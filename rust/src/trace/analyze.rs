//! Critical-path analysis over a collected [`Trace`].
//!
//! Replays the causal DAG (work spans + derived edges) to compute the
//! two classic quantities of work–span analysis:
//!
//! * **T1** — total work: the sum of every execution span (what one
//!   worker would take with zero overhead).
//! * **T∞** — the critical path: the longest causally-chained sequence
//!   of spans. No schedule, however many workers, can beat it; `T1/T∞`
//!   is the achievable-speedup ceiling of the dependence graph itself.
//!
//! Both are reported run-wide and per epoch (quiescence is a barrier,
//! so epochs partition the timeline). The **gap attribution** then
//! splits the distance between the ideal makespan `T1/W` and the
//! measured wall window into exec skew, fence waits, spillover
//! serialization, rebalance cost and idle — an exact decomposition
//! (the components sum to the gap by construction), computed along the
//! busiest worker lane.

use super::{EventKind, Trace};
use crate::util::json::Json;

/// The exact decomposition of `window − ideal` (all ns, may be
/// negative for individual components when the run beats the uniform
/// ideal on some axis — the *sum* always equals the gap).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Attribution {
    /// Measured wall window: last span end − first span start.
    pub window_ns: f64,
    /// Ideal makespan `T1 / workers`.
    pub ideal_ns: f64,
    /// `window − ideal`, what the components below sum to.
    pub gap_ns: f64,
    /// Extra local execution on the busiest lane vs the uniform share.
    pub exec_skew_ns: f64,
    /// Spillover (boundary-task) execution on the busiest lane beyond
    /// its uniform share — cross-shard work that serialized there.
    pub spill_serial_ns: f64,
    /// Time the busiest lane spent in blocked fence-readiness walks.
    pub fence_wait_ns: f64,
    /// Total epoch-boundary rebalance time (coordinator lane).
    pub rebalance_ns: f64,
    /// Residual: window time the busiest lane was neither executing,
    /// fence-walking, nor covered by rebalancing.
    pub idle_ns: f64,
}

impl Attribution {
    /// The components in report order, with labels.
    pub fn components(&self) -> [(&'static str, f64); 5] {
        [
            ("exec skew", self.exec_skew_ns),
            ("fence waits", self.fence_wait_ns),
            ("spillover serialization", self.spill_serial_ns),
            ("rebalance", self.rebalance_ns),
            ("idle (residual)", self.idle_ns),
        ]
    }
}

/// Work–span numbers for one epoch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EpochAnalysis {
    /// Tasks emitted at the epoch's quiescent point (`u64::MAX` for
    /// the unterminated tail segment).
    pub emitted: u64,
    /// Total work in the epoch (ns).
    pub t1_ns: u64,
    /// Critical path within the epoch (ns).
    pub tinf_ns: u64,
    /// `T1/T∞` for the epoch (1.0 when empty).
    pub speedup_bound: f64,
}

/// The full analysis of one trace.
#[derive(Clone, Debug)]
pub struct Analysis {
    /// Engine the trace came from.
    pub engine: String,
    /// Worker count.
    pub workers: usize,
    /// Timestamp basis (`"wall"` / `"virtual"`).
    pub basis: String,
    /// Collection mode label.
    pub mode: &'static str,
    /// Events in the trace (post mark extraction).
    pub events: usize,
    /// Work spans (exec + spill) analyzed.
    pub work_spans: usize,
    /// Causal edges replayed.
    pub edges: usize,
    /// Events lost to saturation (a lossy trace under-counts T1).
    pub dropped: u64,
    /// Total work (ns).
    pub t1_ns: u64,
    /// Critical path (ns).
    pub tinf_ns: u64,
    /// `T1/T∞` (1.0 for an empty trace).
    pub speedup_bound: f64,
    /// Per-epoch breakdown, in epoch order.
    pub epochs: Vec<EpochAnalysis>,
    /// The gap decomposition.
    pub attribution: Attribution,
}

/// Longest path (ns) through `spans` (indices into `trace.events`)
/// using only edges between them. Events are already sorted by
/// `(start_ns, index)` and every edge points strictly forward in that
/// order, so a single in-order sweep is a topological traversal.
fn critical_path(trace: &Trace, spans: &[usize]) -> u64 {
    let mut dist: std::collections::HashMap<usize, u64> = spans
        .iter()
        .map(|&i| (i, trace.events[i].dur_ns))
        .collect();
    // Every edge points strictly forward in the event order, so
    // relaxing edges in ascending `from` order is a topological sweep:
    // a node's distance is final before any of its out-edges is used.
    let mut edges: Vec<&super::Edge> = trace.edges.iter().collect();
    edges.sort_by_key(|e| e.from);
    for e in edges {
        let (Some(&df), Some(dt)) = (dist.get(&e.from), dist.get(&e.to).copied()) else {
            continue;
        };
        let cand = df + trace.events[e.to].dur_ns;
        if cand > dt {
            dist.insert(e.to, cand);
        }
    }
    dist.values().copied().max().unwrap_or(0)
}

/// Analyze a trace: T1, T∞, per-epoch bounds, gap attribution.
pub fn analyze(trace: &Trace) -> Analysis {
    let work = trace.work_spans();
    let t1_ns: u64 = work.iter().map(|&i| trace.events[i].dur_ns).sum();
    let tinf_ns = critical_path(trace, &work);
    let speedup_bound = if tinf_ns == 0 {
        1.0
    } else {
        t1_ns as f64 / tinf_ns as f64
    };

    // Epochs: quiescence marks partition the timeline; a span belongs
    // to the first epoch whose mark is at-or-after its end. Spans past
    // the last mark form the tail segment.
    let mut epochs = Vec::new();
    let n_segments = trace.epoch_marks.len() + 1;
    let mut per_epoch: Vec<Vec<usize>> = vec![Vec::new(); n_segments];
    for &i in &work {
        let end = trace.events[i].end_ns();
        let seg = trace
            .epoch_marks
            .iter()
            .position(|m| m.t_ns >= end)
            .unwrap_or(trace.epoch_marks.len());
        per_epoch[seg].push(i);
    }
    for (seg, spans) in per_epoch.iter().enumerate() {
        if spans.is_empty() {
            continue;
        }
        let t1: u64 = spans.iter().map(|&i| trace.events[i].dur_ns).sum();
        let tinf = critical_path(trace, spans);
        epochs.push(EpochAnalysis {
            emitted: trace
                .epoch_marks
                .get(seg)
                .map(|m| m.emitted)
                .unwrap_or(u64::MAX),
            t1_ns: t1,
            tinf_ns: tinf,
            speedup_bound: if tinf == 0 { 1.0 } else { t1 as f64 / tinf as f64 },
        });
    }

    Analysis {
        engine: trace.engine.clone(),
        workers: trace.workers,
        basis: trace.basis.clone(),
        mode: trace.mode.label(),
        events: trace.events.len(),
        work_spans: work.len(),
        edges: trace.edges.len(),
        dropped: trace.dropped,
        t1_ns,
        tinf_ns,
        speedup_bound,
        epochs,
        attribution: attribute(trace, t1_ns),
    }
}

/// Decompose `window − T1/W` along the busiest worker lane. The five
/// components sum to the gap exactly (see the struct docs): idle is
/// defined as the residual, and the skew terms are busiest-lane time
/// minus the uniform share.
fn attribute(trace: &Trace, t1_ns: u64) -> Attribution {
    let w = trace.workers.max(1) as f64;
    let spans: Vec<_> = trace
        .events
        .iter()
        .filter(|e| e.kind.is_span())
        .collect();
    if spans.is_empty() {
        return Attribution::default();
    }
    let start = spans.iter().map(|e| e.start_ns).min().unwrap_or(0);
    let end = spans.iter().map(|e| e.end_ns()).max().unwrap_or(0);
    let window_ns = end.saturating_sub(start) as f64;
    let ideal_ns = t1_ns as f64 / w;
    let gap_ns = window_ns - ideal_ns;

    // Per worker lane: local exec, spillover exec, fence-walk time.
    let lanes = trace.workers.max(1);
    let mut exec = vec![0f64; lanes];
    let mut spill = vec![0f64; lanes];
    let mut fence = vec![0f64; lanes];
    let mut rebalance_ns = 0f64;
    let mut exec_tot = 0f64;
    let mut spill_tot = 0f64;
    for e in &spans {
        let lane = e.lane as usize;
        match e.kind {
            EventKind::Exec if lane < lanes => {
                exec[lane] += e.dur_ns as f64;
                exec_tot += e.dur_ns as f64;
            }
            EventKind::Spill if lane < lanes => {
                spill[lane] += e.dur_ns as f64;
                spill_tot += e.dur_ns as f64;
            }
            EventKind::FenceWait if lane < lanes => fence[lane] += e.dur_ns as f64,
            EventKind::Rebalance => rebalance_ns += e.dur_ns as f64,
            _ => {}
        }
    }
    let busiest = (0..lanes)
        .max_by(|&a, &b| {
            (exec[a] + spill[a])
                .partial_cmp(&(exec[b] + spill[b]))
                .unwrap_or(std::cmp::Ordering::Equal)
        })
        .unwrap_or(0);
    let exec_skew_ns = exec[busiest] - exec_tot / w;
    let spill_serial_ns = spill[busiest] - spill_tot / w;
    let fence_wait_ns = fence[busiest];
    let idle_ns =
        window_ns - exec[busiest] - spill[busiest] - fence_wait_ns - rebalance_ns;
    Attribution {
        window_ns,
        ideal_ns,
        gap_ns,
        exec_skew_ns,
        spill_serial_ns,
        fence_wait_ns,
        rebalance_ns,
        idle_ns,
    }
}

/// Format ns adaptively (`ns` / `µs` / `ms` / `s`).
pub fn fmt_ns(ns: f64) -> String {
    let a = ns.abs();
    if a < 1_000.0 {
        format!("{ns:.0} ns")
    } else if a < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if a < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

impl Analysis {
    /// Human-readable report (`cli trace-analyze`).
    pub fn render_text(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "trace: engine={} workers={} basis={} mode={} events={} work-spans={} edges={} dropped={}",
            self.engine,
            self.workers,
            self.basis,
            self.mode,
            self.events,
            self.work_spans,
            self.edges,
            self.dropped,
        );
        let _ = writeln!(out, "  T1 (total work)     = {}", fmt_ns(self.t1_ns as f64));
        let _ = writeln!(out, "  T∞ (critical path)  = {}", fmt_ns(self.tinf_ns as f64));
        let _ = writeln!(
            out,
            "  speedup bound T1/T∞ = {:.2}x  ({} workers available)",
            self.speedup_bound, self.workers
        );
        if !self.epochs.is_empty() {
            let _ = writeln!(out, "per-epoch:");
            let _ = writeln!(
                out,
                "  {:>10}  {:>12}  {:>12}  {:>7}",
                "emitted", "T1", "T∞", "bound"
            );
            for e in &self.epochs {
                let emitted = if e.emitted == u64::MAX {
                    "(tail)".to_string()
                } else {
                    e.emitted.to_string()
                };
                let _ = writeln!(
                    out,
                    "  {:>10}  {:>12}  {:>12}  {:>6.2}x",
                    emitted,
                    fmt_ns(e.t1_ns as f64),
                    fmt_ns(e.tinf_ns as f64),
                    e.speedup_bound
                );
            }
        }
        let a = &self.attribution;
        let _ = writeln!(
            out,
            "gap attribution (window {}, ideal T1/W {}, gap {}):",
            fmt_ns(a.window_ns),
            fmt_ns(a.ideal_ns),
            fmt_ns(a.gap_ns)
        );
        for (label, v) in a.components() {
            let share = if a.gap_ns.abs() > f64::EPSILON {
                format!("{:>6.1}%", 100.0 * v / a.gap_ns)
            } else {
                "     —".to_string()
            };
            let _ = writeln!(out, "  {label:<24} {:>12}  {share}", fmt_ns(v));
        }
        if self.dropped > 0 {
            let _ = writeln!(
                out,
                "note: {} events dropped at collection — T1 and the attribution under-count.",
                self.dropped
            );
        }
        out
    }

    /// The `--json` form of the report.
    pub fn to_json(&self) -> Json {
        let a = &self.attribution;
        Json::Obj(vec![
            ("engine".to_string(), Json::from(self.engine.clone())),
            ("workers".to_string(), Json::from(self.workers)),
            ("basis".to_string(), Json::from(self.basis.clone())),
            ("mode".to_string(), Json::from(self.mode)),
            ("events".to_string(), Json::from(self.events)),
            ("work_spans".to_string(), Json::from(self.work_spans)),
            ("edges".to_string(), Json::from(self.edges)),
            ("dropped".to_string(), Json::from(self.dropped)),
            ("t1_ns".to_string(), Json::from(self.t1_ns)),
            ("tinf_ns".to_string(), Json::from(self.tinf_ns)),
            ("speedup_bound".to_string(), Json::from(self.speedup_bound)),
            (
                "epochs".to_string(),
                Json::Arr(
                    self.epochs
                        .iter()
                        .map(|e| {
                            Json::Obj(vec![
                                (
                                    "emitted".to_string(),
                                    if e.emitted == u64::MAX {
                                        Json::Null
                                    } else {
                                        Json::from(e.emitted)
                                    },
                                ),
                                ("t1_ns".to_string(), Json::from(e.t1_ns)),
                                ("tinf_ns".to_string(), Json::from(e.tinf_ns)),
                                (
                                    "speedup_bound".to_string(),
                                    Json::from(e.speedup_bound),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "attribution".to_string(),
                Json::Obj(vec![
                    ("window_ns".to_string(), Json::from(a.window_ns)),
                    ("ideal_ns".to_string(), Json::from(a.ideal_ns)),
                    ("gap_ns".to_string(), Json::from(a.gap_ns)),
                    ("exec_skew_ns".to_string(), Json::from(a.exec_skew_ns)),
                    (
                        "spill_serial_ns".to_string(),
                        Json::from(a.spill_serial_ns),
                    ),
                    ("fence_wait_ns".to_string(), Json::from(a.fence_wait_ns)),
                    ("rebalance_ns".to_string(), Json::from(a.rebalance_ns)),
                    ("idle_ns".to_string(), Json::from(a.idle_ns)),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Edge, EdgeKind, EpochMark, Event, EventKind, TraceMode, NONE_ID, NONE_SHARD};

    fn ev(lane: u32, kind: EventKind, task: u64, start: u64, dur: u64) -> Event {
        Event {
            lane,
            kind,
            task,
            block: NONE_ID,
            shard: NONE_SHARD,
            start_ns: start,
            dur_ns: dur,
        }
    }

    fn trace(events: Vec<Event>, edges: Vec<Edge>, marks: Vec<EpochMark>, workers: usize) -> Trace {
        Trace {
            engine: "test".to_string(),
            workers,
            shards: 0,
            mode: TraceMode::Spans,
            basis: "wall".to_string(),
            events,
            edges,
            epoch_marks: marks,
            dropped: 0,
        }
    }

    #[test]
    fn t1_is_the_sum_of_work_spans_and_tinf_follows_edges() {
        // Chain 0→1 (100+50), task 2 independent (70).
        let t = trace(
            vec![
                ev(0, EventKind::Exec, 0, 0, 100),
                ev(1, EventKind::Exec, 2, 0, 70),
                ev(0, EventKind::Exec, 1, 100, 50),
            ],
            vec![Edge { from: 0, to: 2, kind: EdgeKind::Footprint }],
            vec![],
            2,
        );
        let a = analyze(&t);
        assert_eq!(a.t1_ns, 220);
        assert_eq!(a.tinf_ns, 150, "critical path is the 0→1 chain");
        assert!((a.speedup_bound - 220.0 / 150.0).abs() < 1e-9);
        assert!(a.tinf_ns <= a.t1_ns);
    }

    #[test]
    fn no_edges_means_critical_path_is_the_longest_span() {
        let t = trace(
            vec![
                ev(0, EventKind::Exec, 0, 0, 40),
                ev(1, EventKind::Exec, 1, 0, 90),
            ],
            vec![],
            vec![],
            2,
        );
        let a = analyze(&t);
        assert_eq!(a.t1_ns, 130);
        assert_eq!(a.tinf_ns, 90);
    }

    #[test]
    fn fully_ordered_trace_has_t1_equal_tinf() {
        // Sequential-engine shape: order edges chain every span.
        let t = trace(
            vec![
                ev(0, EventKind::Exec, 0, 0, 10),
                ev(0, EventKind::Exec, 1, 10, 20),
                ev(0, EventKind::Exec, 2, 30, 30),
            ],
            vec![
                Edge { from: 0, to: 1, kind: EdgeKind::Order },
                Edge { from: 1, to: 2, kind: EdgeKind::Order },
            ],
            vec![],
            1,
        );
        let a = analyze(&t);
        assert_eq!(a.t1_ns, 60);
        assert_eq!(a.tinf_ns, 60);
        assert!((a.speedup_bound - 1.0).abs() < 1e-9);
    }

    #[test]
    fn epochs_partition_spans_by_quiescence_marks() {
        let t = trace(
            vec![
                ev(0, EventKind::Exec, 0, 0, 10),
                ev(0, EventKind::Exec, 1, 20, 10),
                ev(0, EventKind::Exec, 2, 60, 10),
            ],
            vec![],
            vec![EpochMark { emitted: 2, t_ns: 40 }],
            1,
        );
        let a = analyze(&t);
        assert_eq!(a.epochs.len(), 2);
        assert_eq!(a.epochs[0].emitted, 2);
        assert_eq!(a.epochs[0].t1_ns, 20);
        assert_eq!(a.epochs[1].emitted, u64::MAX, "tail segment");
        assert_eq!(a.epochs[1].t1_ns, 10);
        let epoch_sum: u64 = a.epochs.iter().map(|e| e.t1_ns).sum();
        assert_eq!(epoch_sum, a.t1_ns, "epochs partition the work");
    }

    #[test]
    fn attribution_components_sum_to_the_gap_exactly() {
        let t = trace(
            vec![
                ev(0, EventKind::Exec, 0, 0, 100),
                ev(0, EventKind::Spill, 1, 100, 40),
                ev(0, EventKind::FenceWait, 1, 140, 10),
                ev(1, EventKind::Exec, 2, 0, 30),
                ev(2, EventKind::Rebalance, 1, 160, 20),
            ],
            vec![],
            vec![],
            2,
        );
        let a = analyze(&t);
        let at = &a.attribution;
        assert_eq!(at.window_ns, 180.0);
        assert_eq!(at.ideal_ns, 170.0 / 2.0);
        let sum: f64 = at.components().iter().map(|(_, v)| v).sum();
        assert!(
            (sum - at.gap_ns).abs() < 1e-6,
            "components {sum} must sum to gap {}",
            at.gap_ns
        );
        assert_eq!(at.fence_wait_ns, 10.0);
        assert_eq!(at.rebalance_ns, 20.0);
        // Busiest lane is 0 (140 vs 30).
        assert_eq!(at.exec_skew_ns, 100.0 - 130.0 / 2.0);
        assert_eq!(at.spill_serial_ns, 40.0 - 40.0 / 2.0);
    }

    #[test]
    fn empty_trace_analyzes_cleanly() {
        let t = trace(vec![], vec![], vec![], 4);
        let a = analyze(&t);
        assert_eq!(a.t1_ns, 0);
        assert_eq!(a.tinf_ns, 0);
        assert_eq!(a.speedup_bound, 1.0);
        assert!(a.epochs.is_empty());
        assert_eq!(a.attribution, Attribution::default());
        assert!(!a.render_text().is_empty());
    }

    #[test]
    fn report_renders_and_serializes() {
        let t = trace(
            vec![
                ev(0, EventKind::Exec, 0, 0, 1500),
                ev(1, EventKind::Exec, 1, 0, 2500),
            ],
            vec![],
            vec![EpochMark { emitted: 2, t_ns: 3000 }],
            2,
        );
        let a = analyze(&t);
        let text = a.render_text();
        assert!(text.contains("T1 (total work)"));
        assert!(text.contains("speedup bound"));
        assert!(text.contains("gap attribution"));
        let j = a.to_json();
        assert_eq!(j.get("t1_ns").unwrap().as_i64(), Some(4000));
        assert_eq!(j.get("tinf_ns").unwrap().as_i64(), Some(2500));
        assert!(j.get("attribution").unwrap().get("window_ns").is_some());
        assert_eq!(j.get("epochs").unwrap().as_arr().unwrap().len(), 1);
        // The JSON must round-trip through the crate parser.
        assert_eq!(Json::parse(&j.render()).unwrap(), j);
    }

    #[test]
    fn fmt_ns_is_adaptive() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2_500_000.0), "2.50 ms");
        assert_eq!(fmt_ns(3_000_000_000.0), "3.000 s");
    }
}
