//! Causal task tracing (DESIGN.md §12): opt-in, always-cheap timeline
//! spans + causal edges riding the telemetry ring infrastructure.
//!
//! Layering:
//!
//! * [`TraceMode`] — `Off` (zero recording, the default), `Spans`
//!   (execution/spillover/fence-wait/rebalance spans + epoch marks),
//!   `Full` (adds idle/walk/quiescence spans and runtime fence-clear
//!   events). Defaults from `ADAPAR_TRACE`. Like `--telemetry`, the
//!   mode is **semantically inert**: nothing recorded here feeds back
//!   into execution, so the observation trace is byte-identical in
//!   every mode (asserted by the conformance matrix).
//! * [`TraceCore`] — per-lane SPSC [`WideRing<4>`]s (one lane per
//!   worker plus a coordinator lane) drained by a background
//!   aggregator thread ("adapar-trace") into an event buffer. A full
//!   ring **drops whole events** (counted), it never blocks a worker;
//!   the buffer itself is capped ([`EVENT_CAP`]) with the overflow
//!   counted too.
//! * [`Trace`] — the immutable post-run view: events sorted on a
//!   global timeline, causal [`Edge`]s derived post hoc (canonical
//!   footprint order per block, program order on the sequential
//!   engine, fence releases in `Full` mode), and the epoch-quiescence
//!   marks. Consumed by the Perfetto exporter ([`perfetto`]) and the
//!   critical-path analyzer ([`analyze`]).
//!
//! Timestamps are nanoseconds relative to the run's start: wall-clock
//! on the threaded engines ([`TraceHandle::now`]/[`TraceHandle::rel`]),
//! deterministic virtual time on the DES testbed (which passes its own
//! clocks explicitly). A span is recorded *after* it ends — one ring
//! push per span, nothing on the span-open path.

pub mod analyze;
pub mod perfetto;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::telemetry::WideRing;
use crate::util::json::Json;

/// Sentinel for "no task id" / "no block id" in an [`Event`].
pub const NONE_ID: u64 = u64::MAX;
/// Sentinel for "no shard" in an [`Event`].
pub const NONE_SHARD: u32 = u32::MAX;
/// Hard cap on buffered events per run (~40 MB of [`Event`]s); events
/// beyond it are dropped and counted, never reallocated without bound.
pub const EVENT_CAP: usize = 1 << 20;

/// Per-lane trace ring capacity (slots). The aggregator drains every
/// ~200 µs, so this bounds burst tolerance, not throughput.
const RING_CAPACITY: usize = 8192;

/// Causal-tracing mode for one run (inert in every mode).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TraceMode {
    /// No recording at all: one predicted-false branch per site.
    #[default]
    Off,
    /// Execution, spillover, fence-wait and rebalance spans plus epoch
    /// marks — enough for the Perfetto timeline and the critical-path
    /// analysis.
    Spans,
    /// Everything in `Spans` plus idle/walk/quiescence spans and
    /// runtime fence-clear events (flow-arrow sources).
    Full,
}

impl TraceMode {
    /// Mode from `ADAPAR_TRACE` (`spans` → [`Spans`],
    /// `full`/`on`/`1`/`true` → [`Full`], anything else / unset →
    /// [`Off`]).
    ///
    /// [`Spans`]: TraceMode::Spans
    /// [`Full`]: TraceMode::Full
    pub fn env_default() -> Self {
        match std::env::var("ADAPAR_TRACE").as_deref() {
            Ok("spans") => TraceMode::Spans,
            Ok("full") | Ok("on") | Ok("1") | Ok("true") => TraceMode::Full,
            _ => TraceMode::Off,
        }
    }

    /// Whether any recording happens.
    pub fn enabled(self) -> bool {
        self != TraceMode::Off
    }

    /// Whether the verbose (`Full`) layer is on.
    pub fn is_full(self) -> bool {
        self == TraceMode::Full
    }

    /// Short label for reports.
    pub fn label(self) -> &'static str {
        match self {
            TraceMode::Off => "off",
            TraceMode::Spans => "spans",
            TraceMode::Full => "full",
        }
    }
}

impl std::str::FromStr for TraceMode {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "off" | "0" | "false" => Ok(TraceMode::Off),
            "spans" => Ok(TraceMode::Spans),
            "full" | "on" | "1" | "true" => Ok(TraceMode::Full),
            _ => Err(format!("unknown trace mode `{s}` (off|spans|full)")),
        }
    }
}

/// What one trace event records.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// A task execution (span; `task` = canonical seq).
    Exec,
    /// A boundary (spillover-chain) task execution (span).
    Spill,
    /// A blocked boundary-readiness walk: the fences of `task` were
    /// not clear (span).
    FenceWait,
    /// An epoch-boundary rebalance (span, coordinator lane; `task` =
    /// blocks migrated).
    Rebalance,
    /// An idle protocol cycle (span, `Full` only).
    Idle,
    /// A chain walk that ended without executing (span, `Full` only).
    Walk,
    /// Epoch-boundary bookkeeping between quiescence and the next
    /// epoch's start (span, coordinator lane, `Full` only).
    Quiesce,
    /// Epoch quiescence reached (point, coordinator lane; `task` =
    /// canonical tasks emitted so far).
    EpochMark,
    /// A completed fence was cleared from a shard chain (point, `Full`
    /// only; `task` = the fence's boundary seq).
    FenceClear,
}

impl EventKind {
    fn from_u8(v: u8) -> Option<EventKind> {
        Some(match v {
            1 => EventKind::Exec,
            2 => EventKind::Spill,
            3 => EventKind::FenceWait,
            4 => EventKind::Rebalance,
            5 => EventKind::Idle,
            6 => EventKind::Walk,
            7 => EventKind::Quiesce,
            8 => EventKind::EpochMark,
            9 => EventKind::FenceClear,
            _ => return None,
        })
    }

    fn as_u8(self) -> u8 {
        match self {
            EventKind::Exec => 1,
            EventKind::Spill => 2,
            EventKind::FenceWait => 3,
            EventKind::Rebalance => 4,
            EventKind::Idle => 5,
            EventKind::Walk => 6,
            EventKind::Quiesce => 7,
            EventKind::EpochMark => 8,
            EventKind::FenceClear => 9,
        }
    }

    /// Stable lowercase name (Perfetto event name, JSON tag).
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Exec => "exec",
            EventKind::Spill => "spill",
            EventKind::FenceWait => "fence_wait",
            EventKind::Rebalance => "rebalance",
            EventKind::Idle => "idle",
            EventKind::Walk => "walk",
            EventKind::Quiesce => "quiesce",
            EventKind::EpochMark => "epoch",
            EventKind::FenceClear => "fence_clear",
        }
    }

    /// Parse a stable name back (the Perfetto round-trip).
    pub fn parse(name: &str) -> Option<EventKind> {
        Some(match name {
            "exec" => EventKind::Exec,
            "spill" => EventKind::Spill,
            "fence_wait" => EventKind::FenceWait,
            "rebalance" => EventKind::Rebalance,
            "idle" => EventKind::Idle,
            "walk" => EventKind::Walk,
            "quiesce" => EventKind::Quiesce,
            "epoch" => EventKind::EpochMark,
            "fence_clear" => EventKind::FenceClear,
            _ => return None,
        })
    }

    /// Whether the kind is a duration span (vs a point event).
    pub fn is_span(self) -> bool {
        !matches!(self, EventKind::EpochMark | EventKind::FenceClear)
    }

    /// Whether the kind represents task work (counts into `T1`).
    pub fn is_work(self) -> bool {
        matches!(self, EventKind::Exec | EventKind::Spill)
    }
}

/// One collected trace event (a span or a point on some lane).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event {
    /// Producer lane: worker id, or `workers` for the coordinator.
    pub lane: u32,
    /// What happened.
    pub kind: EventKind,
    /// Canonical task seq ([`NONE_ID`] when not task-bound; migrated
    /// block count for [`EventKind::Rebalance`], emitted task count
    /// for [`EventKind::EpochMark`]).
    pub task: u64,
    /// Footprint block id ([`NONE_ID`] when unknown).
    pub block: u64,
    /// Shard id ([`NONE_SHARD`] when not shard-bound).
    pub shard: u32,
    /// Start timestamp, ns since run start (wall or virtual).
    pub start_ns: u64,
    /// Duration in ns (0 for point events).
    pub dur_ns: u64,
}

impl Event {
    /// End timestamp (`start + dur`).
    pub fn end_ns(&self) -> u64 {
        self.start_ns.saturating_add(self.dur_ns)
    }
}

/// A causal edge between two events (indices into [`Trace::events`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Edge {
    /// Source event index.
    pub from: usize,
    /// Sink event index.
    pub to: usize,
    /// Why the sink depends on the source.
    pub kind: EdgeKind,
}

/// The causal relationship an [`Edge`] encodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EdgeKind {
    /// Footprint overlap: both tasks touch the same block; the edge
    /// follows canonical task order (the order the engines are bound
    /// to execute conflicting tasks in).
    Footprint,
    /// Sequential program order (consecutive tasks on the sequential
    /// engine — what makes its `T∞` equal `T1`).
    Order,
    /// Fence release: a boundary task's completed fence was cleared,
    /// unblocking the sink.
    Fence,
}

impl EdgeKind {
    /// Stable lowercase name.
    pub fn name(self) -> &'static str {
        match self {
            EdgeKind::Footprint => "footprint",
            EdgeKind::Order => "order",
            EdgeKind::Fence => "fence",
        }
    }

    /// Parse a stable name back.
    pub fn parse(name: &str) -> Option<EdgeKind> {
        Some(match name {
            "footprint" => EdgeKind::Footprint,
            "order" => EdgeKind::Order,
            "fence" => EdgeKind::Fence,
            _ => return None,
        })
    }
}

/// An epoch-quiescence mark on the global timeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EpochMark {
    /// Canonical tasks emitted when the boundary drained.
    pub emitted: u64,
    /// Timestamp of the quiescent point, ns since run start.
    pub t_ns: u64,
}

// ---------------------------------------------------------------------------
// collection (rings + aggregator)
// ---------------------------------------------------------------------------

/// Width of a trace ring slot: `[task, block, start_ns, dur_ns]`.
const W: usize = 4;

fn encode_meta(kind: EventKind, shard: u32) -> u32 {
    let s16 = if shard == NONE_SHARD {
        0xFFFF
    } else {
        (shard & 0xFFFF) as u32
    };
    (kind.as_u8() as u32) | (s16 << 8)
}

fn decode_meta(meta: u32) -> Option<(EventKind, u32)> {
    let kind = EventKind::from_u8((meta & 0xFF) as u8)?;
    let s16 = (meta >> 8) & 0xFFFF;
    let shard = if s16 == 0xFFFF { NONE_SHARD } else { s16 };
    Some((kind, shard))
}

/// The trace aggregator: drain every lane's ring into the event buffer
/// until stopped; the stop flag is checked *before* the drain, so
/// everything pushed before [`TraceCore::finish`] (workers already
/// joined) is collected. Returns the buffer plus the count of events
/// dropped at the buffer cap.
fn collect_loop(rings: &[Arc<WideRing<W>>], stop: &AtomicBool) -> (Vec<Event>, u64) {
    let mut events: Vec<Event> = Vec::new();
    let mut overflow = 0u64;
    loop {
        let stopping = stop.load(Ordering::Acquire);
        for (lane, ring) in rings.iter().enumerate() {
            ring.drain_events(|meta, [task, block, start_ns, dur_ns]| {
                let Some((kind, shard)) = decode_meta(meta) else {
                    return; // unknown tag (corrupt slot): skip, never panic
                };
                if events.len() >= EVENT_CAP {
                    overflow += 1;
                    return;
                }
                events.push(Event {
                    lane: lane as u32,
                    kind,
                    task,
                    block,
                    shard,
                    start_ns,
                    dur_ns,
                });
            });
        }
        if stopping {
            return (events, overflow);
        }
        std::thread::park_timeout(Duration::from_micros(200));
    }
}

struct AggHandle {
    stop: Arc<AtomicBool>,
    thread: std::thread::JoinHandle<(Vec<Event>, u64)>,
}

/// Live trace-collection state for one run: per-lane rings + the
/// background aggregator. Shared by reference with scoped worker
/// threads (all interior state is atomic).
pub struct TraceCore {
    mode: TraceMode,
    workers: usize,
    engine: &'static str,
    basis: &'static str,
    anchor: Instant,
    /// `workers + 1` lanes; the last is the coordinator's.
    rings: Vec<Arc<WideRing<W>>>,
    agg: Option<AggHandle>,
}

impl TraceCore {
    /// Start collection for `workers` lanes (plus the coordinator
    /// lane). Returns `None` when the mode is [`TraceMode::Off`] — the
    /// engines then hand [`TraceHandle::disabled`] to their workers
    /// and the hot path carries one predicted-false branch per site.
    ///
    /// `basis` is `"wall"` or `"virtual"` — the unit of every
    /// timestamp in the finished trace.
    pub fn start(
        mode: TraceMode,
        workers: usize,
        engine: &'static str,
        basis: &'static str,
    ) -> Option<TraceCore> {
        if !mode.enabled() {
            return None;
        }
        let rings: Vec<Arc<WideRing<W>>> = (0..=workers)
            .map(|_| Arc::new(WideRing::new(RING_CAPACITY)))
            .collect();
        let stop = Arc::new(AtomicBool::new(false));
        let t_rings = rings.clone();
        let t_stop = Arc::clone(&stop);
        let thread = std::thread::Builder::new()
            .name("adapar-trace".to_string())
            .spawn(move || collect_loop(&t_rings, &t_stop))
            .expect("spawn trace aggregator");
        Some(TraceCore {
            mode,
            workers,
            engine,
            basis,
            anchor: Instant::now(),
            rings,
            agg: Some(AggHandle { stop, thread }),
        })
    }

    /// The run's trace mode.
    pub fn mode(&self) -> TraceMode {
        self.mode
    }

    /// Recording handle for worker `w`'s lane.
    pub fn handle(&self, worker: usize) -> TraceHandle<'_> {
        debug_assert!(worker < self.workers);
        TraceHandle {
            inner: Some((self, worker as u32)),
        }
    }

    /// Recording handle for the coordinator lane (epoch marks,
    /// rebalance and quiescence spans).
    pub fn coordinator(&self) -> TraceHandle<'_> {
        TraceHandle {
            inner: Some((self, self.workers as u32)),
        }
    }

    /// Stop the aggregator (final drain included) and freeze the
    /// collected trace. Call only after all worker threads have been
    /// joined — that join is the fence making every push visible.
    pub fn finish(mut self) -> Trace {
        let (mut events, overflow) = match self.agg.take() {
            Some(a) => {
                a.stop.store(true, Ordering::Release);
                a.thread.thread().unpark();
                a.thread.join().expect("trace aggregator panicked")
            }
            None => (Vec::new(), 0),
        };
        let dropped =
            overflow + self.rings.iter().map(|r| r.dropped()).sum::<u64>();
        // One global timeline: per-lane order is push order already;
        // interleave lanes by start time (stable, so ties keep lane
        // order deterministic).
        events.sort_by(|a, b| {
            a.start_ns
                .cmp(&b.start_ns)
                .then(a.lane.cmp(&b.lane))
                .then(a.task.cmp(&b.task))
        });
        let epoch_marks = events
            .iter()
            .filter(|e| e.kind == EventKind::EpochMark)
            .map(|e| EpochMark {
                emitted: e.task,
                t_ns: e.start_ns,
            })
            .collect();
        events.retain(|e| e.kind != EventKind::EpochMark);
        let edges = derive_edges(&events, self.engine);
        Trace {
            engine: self.engine.to_string(),
            workers: self.workers,
            shards: 0,
            mode: self.mode,
            basis: self.basis.to_string(),
            events,
            edges,
            epoch_marks,
            dropped,
        }
    }
}

/// A lane's recording handle: every operation is one wait-free ring
/// push (or a predicted-false branch when tracing is off) and never
/// feeds back into execution.
#[derive(Clone, Copy)]
pub struct TraceHandle<'a> {
    inner: Option<(&'a TraceCore, u32)>,
}

impl TraceHandle<'_> {
    /// The no-op handle ([`TraceMode::Off`] / untraced engines).
    pub const fn disabled() -> TraceHandle<'static> {
        TraceHandle { inner: None }
    }

    /// Handle for `lane` of an optional core (the engine-side glue:
    /// `TraceHandle::lane(core.as_ref(), w)`).
    pub fn lane(core: Option<&TraceCore>, lane: usize) -> TraceHandle<'_> {
        match core {
            Some(c) => TraceHandle {
                inner: Some((c, lane as u32)),
            },
            None => TraceHandle { inner: None },
        }
    }

    /// Whether spans are being recorded at all.
    #[inline]
    pub fn active(&self) -> bool {
        self.inner.is_some()
    }

    /// Whether the verbose (`Full`) layer is on.
    #[inline]
    pub fn full(&self) -> bool {
        matches!(self.inner, Some((c, _)) if c.mode.is_full())
    }

    /// Now, in ns since the run's start (0 when disabled — callers
    /// guard with [`active`](Self::active) so the value is never used).
    #[inline]
    pub fn now(&self) -> u64 {
        match self.inner {
            Some((c, _)) => c.anchor.elapsed().as_nanos() as u64,
            None => 0,
        }
    }

    /// Convert an already-taken [`Instant`] to run-relative ns (reuses
    /// clock reads the engine made anyway, e.g. the sharded cost
    /// probe's).
    #[inline]
    pub fn rel(&self, t: Instant) -> u64 {
        match self.inner {
            Some((c, _)) => t.duration_since(c.anchor).as_nanos() as u64,
            None => 0,
        }
    }

    #[inline]
    fn push(&self, kind: EventKind, shard: u32, task: u64, block: u64, start: u64, dur: u64) {
        if let Some((core, lane)) = self.inner {
            core.rings[lane as usize]
                .push_event(encode_meta(kind, shard), &[task, block, start, dur]);
        }
    }

    /// Record a task-execution span.
    #[inline]
    pub fn exec(&self, task: u64, block: u64, shard: u32, start: u64, end: u64) {
        self.push(EventKind::Exec, shard, task, block, start, end.saturating_sub(start));
    }

    /// Record a boundary (spillover) execution span.
    #[inline]
    pub fn spill(&self, task: u64, block: u64, start: u64, end: u64) {
        self.push(EventKind::Spill, NONE_SHARD, task, block, start, end.saturating_sub(start));
    }

    /// Record a blocked fence-readiness walk for boundary `task`.
    #[inline]
    pub fn fence_wait(&self, task: u64, start: u64, end: u64) {
        self.push(
            EventKind::FenceWait,
            NONE_SHARD,
            task,
            NONE_ID,
            start,
            end.saturating_sub(start),
        );
    }

    /// Record a rebalance span (`moves` = migrated blocks).
    #[inline]
    pub fn rebalance(&self, moves: u64, start: u64, end: u64) {
        self.push(
            EventKind::Rebalance,
            NONE_SHARD,
            moves,
            NONE_ID,
            start,
            end.saturating_sub(start),
        );
    }

    /// Record an idle cycle span (`Full` only; no-op otherwise).
    #[inline]
    pub fn idle(&self, start: u64, end: u64) {
        if self.full() {
            self.push(EventKind::Idle, NONE_SHARD, NONE_ID, NONE_ID, start, end.saturating_sub(start));
        }
    }

    /// Record a workless chain-walk span (`Full` only; no-op otherwise).
    #[inline]
    pub fn walk(&self, start: u64, end: u64) {
        if self.full() {
            self.push(EventKind::Walk, NONE_SHARD, NONE_ID, NONE_ID, start, end.saturating_sub(start));
        }
    }

    /// Record an epoch-boundary bookkeeping span (`Full` only).
    #[inline]
    pub fn quiesce(&self, start: u64, end: u64) {
        if self.full() {
            self.push(EventKind::Quiesce, NONE_SHARD, NONE_ID, NONE_ID, start, end.saturating_sub(start));
        }
    }

    /// Record an epoch-quiescence mark at the current wall clock.
    #[inline]
    pub fn epoch_mark(&self, emitted: u64) {
        let t = self.now();
        self.epoch_mark_at(emitted, t);
    }

    /// Record an epoch-quiescence mark at an explicit timestamp (the
    /// virtual engine's deterministic clocks).
    #[inline]
    pub fn epoch_mark_at(&self, emitted: u64, t_ns: u64) {
        self.push(EventKind::EpochMark, NONE_SHARD, emitted, NONE_ID, t_ns, 0);
    }

    /// Record a fence-clear point for boundary `task` (`Full` only).
    #[inline]
    pub fn fence_clear(&self, task: u64) {
        if self.full() {
            let t = self.now();
            self.push(EventKind::FenceClear, NONE_SHARD, task, NONE_ID, t, 0);
        }
    }
}

// ---------------------------------------------------------------------------
// the finished trace + causal-edge derivation
// ---------------------------------------------------------------------------

/// The immutable, post-run causal trace.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    /// Engine label (`"parallel"`, `"sharded"`, ...).
    pub engine: String,
    /// Worker-lane count (lane `workers` is the coordinator).
    pub workers: usize,
    /// Shard count (0 for unsharded engines).
    pub shards: usize,
    /// The mode the trace was collected under.
    pub mode: TraceMode,
    /// Timestamp basis: `"wall"` or `"virtual"`.
    pub basis: String,
    /// All events on one timeline, sorted by `(start_ns, lane)`.
    pub events: Vec<Event>,
    /// Causal edges between events (indices into `events`); acyclic by
    /// construction (every edge points strictly forward on the
    /// `(start_ns, index)` order).
    pub edges: Vec<Edge>,
    /// Epoch-quiescence marks in time order.
    pub epoch_marks: Vec<EpochMark>,
    /// Events lost to ring saturation or the buffer cap.
    pub dropped: u64,
}

impl Trace {
    /// Indices of the work spans (exec + spill), the `T1` population.
    pub fn work_spans(&self) -> Vec<usize> {
        self.events
            .iter()
            .enumerate()
            .filter(|(_, e)| e.kind.is_work())
            .map(|(i, _)| i)
            .collect()
    }

    /// Small summary for `RunReport::to_json` (the full trace goes to
    /// the Perfetto file, not the report).
    pub fn summary_json(&self) -> Json {
        Json::Obj(vec![
            ("mode".to_string(), Json::from(self.mode.label())),
            ("basis".to_string(), Json::from(self.basis.clone())),
            ("events".to_string(), Json::from(self.events.len())),
            ("edges".to_string(), Json::from(self.edges.len())),
            ("epochs".to_string(), Json::from(self.epoch_marks.len())),
            ("dropped".to_string(), Json::from(self.dropped)),
        ])
    }
}

/// `(start, index)` key giving the strict forward order every edge
/// must respect — the acyclicity invariant.
fn order_key(events: &[Event], i: usize) -> (u64, usize) {
    (events[i].start_ns, i)
}

/// Derive causal edges from the collected events.
///
/// * **Footprint** edges chain the work spans touching each block in
///   canonical (seq) order — the dependence order every engine is
///   bound to execute conflicting tasks in, so edges always point
///   forward in time.
/// * **Order** edges chain consecutive work spans on the sequential
///   engine (total program order ⇒ `T∞ == T1`).
/// * **Fence** edges connect a boundary task's span to the first
///   execution on the lane that observed its fence complete
///   ([`EventKind::FenceClear`], `Full` mode) — the released task.
///
/// Every candidate violating the forward `(start_ns, index)` order is
/// discarded, so the result is acyclic unconditionally (even on
/// drop-lossy traces).
fn derive_edges(events: &[Event], engine: &str) -> Vec<Edge> {
    let mut edges: Vec<Edge> = Vec::new();
    let work: Vec<usize> = events
        .iter()
        .enumerate()
        .filter(|(_, e)| e.kind.is_work() && e.task != NONE_ID)
        .map(|(i, _)| i)
        .collect();
    let mut push = |from: usize, to: usize, kind: EdgeKind, edges: &mut Vec<Edge>| {
        if order_key(events, from) < order_key(events, to) {
            edges.push(Edge { from, to, kind });
        }
    };

    // By canonical task order (the seq assigned at creation).
    let mut by_seq = work.clone();
    by_seq.sort_by_key(|&i| events[i].task);

    if engine == "sequential" {
        for pair in by_seq.windows(2) {
            push(pair[0], pair[1], EdgeKind::Order, &mut edges);
        }
    }

    // Footprint: last-writer chains per block, in canonical order.
    let mut last_by_block: std::collections::HashMap<u64, usize> =
        std::collections::HashMap::new();
    for &i in &by_seq {
        let block = events[i].block;
        if block == NONE_ID {
            continue;
        }
        if let Some(&prev) = last_by_block.get(&block) {
            push(prev, i, EdgeKind::Footprint, &mut edges);
        }
        last_by_block.insert(block, i);
    }

    // Fence releases (Full mode): clear point → next execution on the
    // clearing lane; source = the boundary task's own span.
    let mut span_of_task: std::collections::HashMap<u64, usize> =
        std::collections::HashMap::new();
    for &i in &work {
        span_of_task.entry(events[i].task).or_insert(i);
    }
    for (ci, clear) in events.iter().enumerate() {
        if clear.kind != EventKind::FenceClear {
            continue;
        }
        let Some(&from) = span_of_task.get(&clear.task) else {
            continue; // the boundary's own span was dropped
        };
        // First work span on the clearing lane at or after the clear.
        let to = work
            .iter()
            .copied()
            .filter(|&i| {
                events[i].lane == clear.lane
                    && order_key(events, i) > order_key(events, ci)
            })
            .min_by_key(|&i| order_key(events, i));
        if let Some(to) = to {
            push(from, to, EdgeKind::Fence, &mut edges);
        }
    }
    edges.sort_by_key(|e| (e.from, e.to));
    edges.dedup_by_key(|e| (e.from, e.to));
    edges
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(lane: u32, kind: EventKind, task: u64, block: u64, start: u64, dur: u64) -> Event {
        Event {
            lane,
            kind,
            task,
            block,
            shard: NONE_SHARD,
            start_ns: start,
            dur_ns: dur,
        }
    }

    #[test]
    fn mode_parses_and_defaults_off() {
        assert_eq!("off".parse::<TraceMode>().unwrap(), TraceMode::Off);
        assert_eq!("spans".parse::<TraceMode>().unwrap(), TraceMode::Spans);
        assert_eq!("full".parse::<TraceMode>().unwrap(), TraceMode::Full);
        assert!("bogus".parse::<TraceMode>().is_err());
        assert_eq!(TraceMode::default(), TraceMode::Off);
        assert!(!TraceMode::Off.enabled());
        assert!(TraceMode::Spans.enabled() && !TraceMode::Spans.is_full());
        assert!(TraceMode::Full.is_full());
    }

    #[test]
    fn meta_encoding_round_trips() {
        for kind in [
            EventKind::Exec,
            EventKind::Spill,
            EventKind::FenceWait,
            EventKind::Rebalance,
            EventKind::Idle,
            EventKind::Walk,
            EventKind::Quiesce,
            EventKind::EpochMark,
            EventKind::FenceClear,
        ] {
            for shard in [0u32, 7, 65_534, NONE_SHARD] {
                assert_eq!(decode_meta(encode_meta(kind, shard)), Some((kind, shard)));
            }
            assert_eq!(EventKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(decode_meta(0), None, "kind 0 is reserved/invalid");
    }

    #[test]
    fn off_mode_starts_nothing() {
        assert!(TraceCore::start(TraceMode::Off, 4, "parallel", "wall").is_none());
        let h = TraceHandle::disabled();
        assert!(!h.active() && !h.full());
        assert_eq!(h.now(), 0);
        h.exec(1, NONE_ID, NONE_SHARD, 0, 10); // must be a no-op, not a panic
    }

    #[test]
    fn core_collects_spans_and_marks_across_lanes() {
        let core = TraceCore::start(TraceMode::Full, 2, "parallel", "wall").unwrap();
        let w0 = core.handle(0);
        let w1 = core.handle(1);
        assert!(w0.active() && w0.full());
        w0.exec(0, NONE_ID, NONE_SHARD, 10, 30);
        w1.exec(1, NONE_ID, NONE_SHARD, 5, 25);
        w0.idle(30, 40);
        core.coordinator().epoch_mark_at(2, 50);
        let trace = core.finish();
        assert_eq!(trace.engine, "parallel");
        assert_eq!(trace.workers, 2);
        assert_eq!(trace.dropped, 0);
        assert_eq!(trace.epoch_marks, vec![EpochMark { emitted: 2, t_ns: 50 }]);
        // Sorted by start: w1's exec (5) first, then w0's (10), idle (30).
        let kinds: Vec<(u32, EventKind)> =
            trace.events.iter().map(|e| (e.lane, e.kind)).collect();
        assert_eq!(
            kinds,
            vec![
                (1, EventKind::Exec),
                (0, EventKind::Exec),
                (0, EventKind::Idle)
            ]
        );
        assert_eq!(trace.events[0].dur_ns, 20);
        assert_eq!(trace.events[0].end_ns(), 25);
    }

    #[test]
    fn spans_mode_suppresses_full_only_events() {
        let core = TraceCore::start(TraceMode::Spans, 1, "parallel", "wall").unwrap();
        let h = core.handle(0);
        assert!(h.active() && !h.full());
        h.exec(0, NONE_ID, NONE_SHARD, 0, 10);
        h.idle(10, 20);
        h.walk(20, 30);
        h.quiesce(30, 40);
        h.fence_clear(0);
        let trace = core.finish();
        assert_eq!(trace.events.len(), 1, "only the exec span is recorded");
        assert_eq!(trace.events[0].kind, EventKind::Exec);
    }

    #[test]
    fn sequential_order_edges_chain_every_task() {
        let events = vec![
            span(0, EventKind::Exec, 0, NONE_ID, 0, 10),
            span(0, EventKind::Exec, 1, NONE_ID, 12, 10),
            span(0, EventKind::Exec, 2, NONE_ID, 25, 10),
        ];
        let edges = derive_edges(&events, "sequential");
        assert_eq!(
            edges,
            vec![
                Edge { from: 0, to: 1, kind: EdgeKind::Order },
                Edge { from: 1, to: 2, kind: EdgeKind::Order },
            ]
        );
    }

    #[test]
    fn footprint_edges_follow_canonical_order_per_block() {
        // Tasks 0,2 touch block 5; task 1 touches block 9. Wall order
        // differs from seq order across lanes; edges follow seq.
        let events = vec![
            span(1, EventKind::Exec, 1, 9, 0, 5),
            span(0, EventKind::Exec, 0, 5, 1, 5),
            span(0, EventKind::Exec, 2, 5, 8, 5),
        ];
        let edges = derive_edges(&events, "sharded");
        assert_eq!(
            edges,
            vec![Edge { from: 1, to: 2, kind: EdgeKind::Footprint }]
        );
    }

    #[test]
    fn derived_edges_are_acyclic_and_forward() {
        // A degenerate trace (equal starts, duplicate seqs from a lossy
        // ring) must still yield only forward edges.
        let events = vec![
            span(0, EventKind::Exec, 3, 1, 0, 0),
            span(1, EventKind::Exec, 3, 1, 0, 0),
            span(0, EventKind::Exec, 1, 1, 0, 0),
        ];
        let edges = derive_edges(&events, "sharded");
        for e in &edges {
            assert!(order_key(&events, e.from) < order_key(&events, e.to));
        }
    }

    #[test]
    fn fence_clear_edges_point_at_the_released_execution() {
        let mut events = vec![
            span(0, EventKind::Spill, 7, 3, 0, 10), // boundary task 7
            span(1, EventKind::Exec, 8, NONE_ID, 20, 5), // released local
        ];
        events.push(Event {
            lane: 1,
            kind: EventKind::FenceClear,
            task: 7,
            block: NONE_ID,
            shard: NONE_SHARD,
            start_ns: 15,
            dur_ns: 0,
        });
        events.sort_by_key(|e| e.start_ns);
        let edges = derive_edges(&events, "sharded");
        assert!(
            edges.contains(&Edge { from: 0, to: 2, kind: EdgeKind::Fence }),
            "{edges:?}"
        );
    }

    #[test]
    fn ring_saturation_drops_whole_events_and_counts() {
        let core = TraceCore::start(TraceMode::Spans, 1, "parallel", "wall").unwrap();
        let h = core.handle(0);
        // Overfill far beyond the ring capacity faster than the 200µs
        // aggregator cadence can drain — some events must drop, every
        // drop must be counted, and nothing may block.
        let n: u64 = 200_000;
        for t in 0..n {
            h.exec(t, NONE_ID, NONE_SHARD, t, t + 1);
        }
        let trace = core.finish();
        assert_eq!(trace.events.len() as u64 + trace.dropped, n);
        // Whatever survived is well-formed.
        for e in &trace.events {
            assert_eq!(e.kind, EventKind::Exec);
            assert_eq!(e.dur_ns, 1);
        }
    }
}
