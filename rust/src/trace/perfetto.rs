//! Chrome/Perfetto `trace_event` export (and parse-back) for a
//! collected [`Trace`].
//!
//! The file is one JSON object with the standard `traceEvents` array —
//! loadable as-is in `ui.perfetto.dev` or `chrome://tracing` — plus an
//! `"adapar"` sidecar object carrying the trace at full fidelity
//! (events, causal edges, epoch marks, drop counts). Perfetto ignores
//! unknown top-level keys, so one file serves both the human timeline
//! and `cli trace-analyze`, which reads the sidecar back through
//! [`parse`] without any loss.
//!
//! Lane layout:
//! * `pid 1` — one row per worker (`tid` = worker id) plus the
//!   coordinator row (`tid` = worker count): every span and instant.
//! * `pid 2` — one row per shard (sharded engine only): task
//!   executions duplicated onto their shard's row, so per-shard load
//!   is visible at a glance.
//! * Fence releases and spillover-serialization dependencies are
//!   emitted as `s`/`f` flow arrows between the connected spans.

use super::{Edge, EdgeKind, EpochMark, Event, EventKind, Trace, TraceMode, NONE_ID, NONE_SHARD};
use crate::util::json::Json;

/// µs with fractional ns, the unit `trace_event` timestamps use.
fn us(ns: u64) -> Json {
    Json::Float(ns as f64 / 1000.0)
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// `task`/`block` ids for the sidecar: `null` for the none sentinel so
/// the round trip is exact even though `u64::MAX` itself is not
/// representable as a JSON integer.
fn id_json(v: u64) -> Json {
    if v == NONE_ID {
        Json::Null
    } else {
        Json::from(v)
    }
}

fn shard_json(v: u32) -> Json {
    if v == NONE_SHARD {
        Json::Null
    } else {
        Json::from(v)
    }
}

fn span_args(e: &Event) -> Json {
    let mut fields: Vec<(&str, Json)> = Vec::new();
    if e.task != NONE_ID {
        let key = match e.kind {
            EventKind::Rebalance => "moves",
            EventKind::EpochMark => "emitted",
            _ => "task",
        };
        fields.push((key, Json::from(e.task)));
    }
    if e.block != NONE_ID {
        fields.push(("block", Json::from(e.block)));
    }
    if e.shard != NONE_SHARD {
        fields.push(("shard", Json::from(e.shard)));
    }
    obj(fields)
}

/// Render `trace` as a Perfetto-loadable `trace_event` JSON document
/// (with the full-fidelity `adapar` sidecar).
pub fn export(trace: &Trace) -> String {
    let mut te: Vec<Json> = Vec::new();

    // Process/thread naming metadata.
    te.push(obj(vec![
        ("ph", Json::from("M")),
        ("pid", Json::from(1u32)),
        ("name", Json::from("process_name")),
        (
            "args",
            obj(vec![(
                "name",
                Json::from(format!("adapar {} workers", trace.engine)),
            )]),
        ),
    ]));
    for w in 0..=trace.workers {
        let label = if w == trace.workers {
            "coordinator".to_string()
        } else {
            format!("worker {w}")
        };
        te.push(obj(vec![
            ("ph", Json::from("M")),
            ("pid", Json::from(1u32)),
            ("tid", Json::from(w)),
            ("name", Json::from("thread_name")),
            ("args", obj(vec![("name", Json::from(label))])),
        ]));
    }
    let shards_used = trace.events.iter().any(|e| e.shard != NONE_SHARD);
    if shards_used {
        te.push(obj(vec![
            ("ph", Json::from("M")),
            ("pid", Json::from(2u32)),
            ("name", Json::from("process_name")),
            ("args", obj(vec![("name", Json::from("adapar shards"))])),
        ]));
        let max_shard = trace
            .events
            .iter()
            .filter(|e| e.shard != NONE_SHARD)
            .map(|e| e.shard)
            .max()
            .unwrap_or(0);
        for s in 0..=max_shard {
            te.push(obj(vec![
                ("ph", Json::from("M")),
                ("pid", Json::from(2u32)),
                ("tid", Json::from(s)),
                ("name", Json::from("thread_name")),
                ("args", obj(vec![("name", Json::from(format!("shard {s}")))])),
            ]));
        }
    }

    // Spans and instants on the worker lanes (+ shard-lane duplicates).
    for e in &trace.events {
        if e.kind.is_span() {
            te.push(obj(vec![
                ("ph", Json::from("X")),
                ("pid", Json::from(1u32)),
                ("tid", Json::from(e.lane)),
                ("ts", us(e.start_ns)),
                ("dur", us(e.dur_ns)),
                ("name", Json::from(e.kind.name())),
                ("cat", Json::from("adapar")),
                ("args", span_args(e)),
            ]));
            if e.shard != NONE_SHARD {
                te.push(obj(vec![
                    ("ph", Json::from("X")),
                    ("pid", Json::from(2u32)),
                    ("tid", Json::from(e.shard)),
                    ("ts", us(e.start_ns)),
                    ("dur", us(e.dur_ns)),
                    ("name", Json::from(e.kind.name())),
                    ("cat", Json::from("adapar")),
                    ("args", span_args(e)),
                ]));
            }
        } else {
            te.push(obj(vec![
                ("ph", Json::from("i")),
                ("pid", Json::from(1u32)),
                ("tid", Json::from(e.lane)),
                ("ts", us(e.start_ns)),
                ("name", Json::from(e.kind.name())),
                ("cat", Json::from("adapar")),
                ("s", Json::from("t")),
                ("args", span_args(e)),
            ]));
        }
    }

    // Epoch-quiescence marks: process-scoped instants on the
    // coordinator row.
    for m in &trace.epoch_marks {
        te.push(obj(vec![
            ("ph", Json::from("i")),
            ("pid", Json::from(1u32)),
            ("tid", Json::from(trace.workers)),
            ("ts", us(m.t_ns)),
            ("name", Json::from("epoch")),
            ("cat", Json::from("adapar")),
            ("s", Json::from("p")),
            ("args", obj(vec![("emitted", Json::from(m.emitted))])),
        ]));
    }

    // Flow arrows: fence releases always; footprint dependencies when
    // the source is a spillover execution (the cross-shard
    // serialization the analyzer charges separately).
    let mut flow_id = 0u64;
    for edge in &trace.edges {
        let draw = match edge.kind {
            EdgeKind::Fence => true,
            EdgeKind::Footprint => trace.events[edge.from].kind == EventKind::Spill,
            EdgeKind::Order => false,
        };
        if !draw {
            continue;
        }
        let (from, to) = (&trace.events[edge.from], &trace.events[edge.to]);
        te.push(obj(vec![
            ("ph", Json::from("s")),
            ("pid", Json::from(1u32)),
            ("tid", Json::from(from.lane)),
            ("ts", us(from.end_ns())),
            ("id", Json::from(flow_id)),
            ("name", Json::from(edge.kind.name())),
            ("cat", Json::from("adapar")),
        ]));
        te.push(obj(vec![
            ("ph", Json::from("f")),
            ("pid", Json::from(1u32)),
            ("tid", Json::from(to.lane)),
            ("ts", us(to.start_ns)),
            ("id", Json::from(flow_id)),
            ("name", Json::from(edge.kind.name())),
            ("cat", Json::from("adapar")),
            ("bp", Json::from("e")),
        ]));
        flow_id += 1;
    }

    // Full-fidelity sidecar (what `parse` reads back).
    let sidecar = obj(vec![
        ("engine", Json::from(trace.engine.clone())),
        ("workers", Json::from(trace.workers)),
        ("shards", Json::from(trace.shards)),
        ("mode", Json::from(trace.mode.label())),
        ("basis", Json::from(trace.basis.clone())),
        ("dropped", Json::from(trace.dropped)),
        (
            "epoch_marks",
            Json::Arr(
                trace
                    .epoch_marks
                    .iter()
                    .map(|m| {
                        obj(vec![
                            ("emitted", Json::from(m.emitted)),
                            ("t_ns", Json::from(m.t_ns)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "events",
            Json::Arr(
                trace
                    .events
                    .iter()
                    .map(|e| {
                        obj(vec![
                            ("lane", Json::from(e.lane)),
                            ("kind", Json::from(e.kind.name())),
                            ("task", id_json(e.task)),
                            ("block", id_json(e.block)),
                            ("shard", shard_json(e.shard)),
                            ("start_ns", Json::from(e.start_ns)),
                            ("dur_ns", Json::from(e.dur_ns)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "edges",
            Json::Arr(
                trace
                    .edges
                    .iter()
                    .map(|e| {
                        obj(vec![
                            ("from", Json::from(e.from)),
                            ("to", Json::from(e.to)),
                            ("kind", Json::from(e.kind.name())),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);

    Json::Obj(vec![
        ("traceEvents".to_string(), Json::Arr(te)),
        ("displayTimeUnit".to_string(), Json::from("ns")),
        ("adapar".to_string(), sidecar),
    ])
    .render()
}

fn need<'a>(j: &'a Json, key: &str) -> Result<&'a Json, String> {
    j.get(key).ok_or_else(|| format!("missing `{key}`"))
}

fn need_u64(j: &Json, key: &str) -> Result<u64, String> {
    need(j, key)?
        .as_i64()
        .filter(|v| *v >= 0)
        .map(|v| v as u64)
        .ok_or_else(|| format!("`{key}` is not a non-negative integer"))
}

fn id_from(j: &Json, key: &str) -> Result<u64, String> {
    match need(j, key)? {
        Json::Null => Ok(NONE_ID),
        v => v
            .as_i64()
            .filter(|v| *v >= 0)
            .map(|v| v as u64)
            .ok_or_else(|| format!("`{key}` is not an id or null")),
    }
}

/// Reconstruct a [`Trace`] from an exported file (the `adapar`
/// sidecar). Exact inverse of [`export`].
pub fn parse(text: &str) -> Result<Trace, String> {
    let doc = Json::parse(text)?;
    let side = doc
        .get("adapar")
        .ok_or("not an adapar trace: no `adapar` sidecar object")?;
    let mode: TraceMode = need(side, "mode")?
        .as_str()
        .ok_or("`mode` is not a string")?
        .parse()?;
    let mut events = Vec::new();
    for ev in need(side, "events")?.as_arr().ok_or("`events` is not an array")? {
        let kind_name = need(ev, "kind")?.as_str().ok_or("event `kind` not a string")?;
        let kind = EventKind::parse(kind_name)
            .ok_or_else(|| format!("unknown event kind `{kind_name}`"))?;
        let shard = match need(ev, "shard")? {
            Json::Null => NONE_SHARD,
            v => v
                .as_i64()
                .filter(|v| *v >= 0)
                .map(|v| v as u32)
                .ok_or("event `shard` is not a shard id or null")?,
        };
        events.push(Event {
            lane: need_u64(ev, "lane")? as u32,
            kind,
            task: id_from(ev, "task")?,
            block: id_from(ev, "block")?,
            shard,
            start_ns: need_u64(ev, "start_ns")?,
            dur_ns: need_u64(ev, "dur_ns")?,
        });
    }
    let mut edges = Vec::new();
    for ed in need(side, "edges")?.as_arr().ok_or("`edges` is not an array")? {
        let kind_name = need(ed, "kind")?.as_str().ok_or("edge `kind` not a string")?;
        let kind = EdgeKind::parse(kind_name)
            .ok_or_else(|| format!("unknown edge kind `{kind_name}`"))?;
        let from = need_u64(ed, "from")? as usize;
        let to = need_u64(ed, "to")? as usize;
        if from >= events.len() || to >= events.len() {
            return Err(format!("edge {from}->{to} out of bounds"));
        }
        edges.push(Edge { from, to, kind });
    }
    let mut epoch_marks = Vec::new();
    for m in need(side, "epoch_marks")?
        .as_arr()
        .ok_or("`epoch_marks` is not an array")?
    {
        epoch_marks.push(EpochMark {
            emitted: need_u64(m, "emitted")?,
            t_ns: need_u64(m, "t_ns")?,
        });
    }
    Ok(Trace {
        engine: need(side, "engine")?
            .as_str()
            .ok_or("`engine` is not a string")?
            .to_string(),
        workers: need_u64(side, "workers")? as usize,
        shards: need_u64(side, "shards")? as usize,
        mode,
        basis: need(side, "basis")?
            .as_str()
            .ok_or("`basis` is not a string")?
            .to_string(),
        events,
        edges,
        epoch_marks,
        dropped: need_u64(side, "dropped")?,
    })
}

/// Structural validation that an exported document is
/// Perfetto-loadable: parses as one JSON object, `traceEvents` is an
/// array, and every entry has a `ph` plus the fields its phase
/// requires. Returns the `traceEvents` count.
pub fn validate_structure(text: &str) -> Result<usize, String> {
    let doc = Json::parse(text)?;
    let te = need(&doc, "traceEvents")?
        .as_arr()
        .ok_or("`traceEvents` is not an array")?;
    for (i, ev) in te.iter().enumerate() {
        let ph = ev
            .get("ph")
            .and_then(|p| p.as_str())
            .ok_or_else(|| format!("traceEvents[{i}]: missing `ph`"))?;
        let req: &[&str] = match ph {
            "X" => &["pid", "tid", "ts", "dur", "name"],
            "i" => &["pid", "tid", "ts", "name", "s"],
            "s" | "f" => &["pid", "tid", "ts", "id", "name"],
            "M" => &["pid", "name", "args"],
            _ => return Err(format!("traceEvents[{i}]: unexpected phase `{ph}`")),
        };
        for key in req {
            if ev.get(key).is_none() {
                return Err(format!("traceEvents[{i}] (`{ph}`): missing `{key}`"));
            }
        }
        if ph == "X" {
            let dur = ev.get("dur").and_then(|d| d.as_f64()).unwrap_or(-1.0);
            if dur < 0.0 {
                return Err(format!("traceEvents[{i}]: negative duration"));
            }
        }
    }
    Ok(te.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_trace() -> Trace {
        let events = vec![
            Event {
                lane: 0,
                kind: EventKind::Spill,
                task: 3,
                block: 9,
                shard: NONE_SHARD,
                start_ns: 0,
                dur_ns: 50,
            },
            Event {
                lane: 1,
                kind: EventKind::Exec,
                task: 4,
                block: 9,
                shard: 1,
                start_ns: 60,
                dur_ns: 40,
            },
            Event {
                lane: 1,
                kind: EventKind::FenceWait,
                task: 3,
                block: NONE_ID,
                shard: NONE_SHARD,
                start_ns: 10,
                dur_ns: 20,
            },
            Event {
                lane: 2,
                kind: EventKind::Rebalance,
                task: 2,
                block: NONE_ID,
                shard: NONE_SHARD,
                start_ns: 120,
                dur_ns: 15,
            },
        ];
        Trace {
            engine: "sharded".to_string(),
            workers: 2,
            shards: 2,
            mode: TraceMode::Full,
            basis: "wall".to_string(),
            edges: vec![Edge {
                from: 0,
                to: 1,
                kind: EdgeKind::Footprint,
            }],
            epoch_marks: vec![EpochMark {
                emitted: 5,
                t_ns: 110,
            }],
            dropped: 7,
            events,
        }
    }

    #[test]
    fn export_parse_round_trips_exactly() {
        let trace = sample_trace();
        let text = export(&trace);
        let back = parse(&text).expect("parse back");
        assert_eq!(back.engine, trace.engine);
        assert_eq!(back.workers, trace.workers);
        assert_eq!(back.shards, trace.shards);
        assert_eq!(back.mode, trace.mode);
        assert_eq!(back.basis, trace.basis);
        assert_eq!(back.events, trace.events);
        assert_eq!(back.edges, trace.edges);
        assert_eq!(back.epoch_marks, trace.epoch_marks);
        assert_eq!(back.dropped, trace.dropped);
    }

    #[test]
    fn export_is_structurally_perfetto_loadable() {
        let text = export(&sample_trace());
        let n = validate_structure(&text).expect("structurally valid");
        // 4 span events + 1 shard-lane duplicate + 1 epoch instant +
        // 1 flow pair + metadata rows (1 process + 3 threads + 1 shard
        // process + 2 shard threads).
        assert_eq!(n, 4 + 1 + 1 + 2 + 7);
    }

    #[test]
    fn spill_footprint_edges_become_flow_arrows() {
        let text = export(&sample_trace());
        let doc = Json::parse(&text).unwrap();
        let te = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let flows: Vec<&str> = te
            .iter()
            .filter(|e| matches!(e.get("ph").and_then(|p| p.as_str()), Some("s" | "f")))
            .map(|e| e.get("name").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(flows, vec!["footprint", "footprint"], "one s/f pair");
    }

    #[test]
    fn parse_rejects_non_trace_documents() {
        assert!(parse("{}").is_err());
        assert!(parse("[1,2]").is_err());
        assert!(parse("not json").is_err());
        // Sidecar with a dangling edge index.
        let bad = r#"{"traceEvents":[],"adapar":{"engine":"e","workers":1,"shards":0,
            "mode":"spans","basis":"wall","dropped":0,"epoch_marks":[],
            "events":[],"edges":[{"from":0,"to":1,"kind":"fence"}]}}"#;
        assert!(parse(bad).is_err());
    }

    #[test]
    fn timestamps_are_fractional_microseconds() {
        let trace = sample_trace();
        let text = export(&trace);
        let doc = Json::parse(&text).unwrap();
        let te = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let spill = te
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("spill"))
            .unwrap();
        assert_eq!(spill.get("ts").unwrap().as_f64(), Some(0.0));
        assert_eq!(spill.get("dur").unwrap().as_f64(), Some(0.05));
    }
}
