//! A counting global allocator for allocation-profiling benches.
//!
//! The arena refactor's acceptance criterion is *zero steady-state
//! allocations in the execution loop* (DESIGN.md §3); asserting that
//! needs byte-accurate numbers, not intuition. `bench_chain` installs
//! [`Counting`] as the global allocator when built with the
//! `bench-alloc` cargo feature and reads the counters around each run:
//!
//! ```ignore
//! #[cfg(feature = "bench-alloc")]
//! #[global_allocator]
//! static ALLOC: adapar::util::alloc::Counting = adapar::util::alloc::Counting;
//! ```
//!
//! The type is always compiled (it is plain code with no cost unless
//! installed); only the *installation* is feature-gated, because a
//! global allocator affects every test and bench in the build.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCATED_BYTES: AtomicU64 = AtomicU64::new(0);
static ALLOCATION_COUNT: AtomicU64 = AtomicU64::new(0);
static LIVE_BYTES: AtomicU64 = AtomicU64::new(0);
static PEAK_BYTES: AtomicU64 = AtomicU64::new(0);

/// Record `size` freshly-allocated bytes: bump the live gauge and
/// CAS-max it into the peak. Relaxed everywhere — the gauges are
/// measurements, not synchronization.
fn note_alloc(size: u64) {
    let live = LIVE_BYTES.fetch_add(size, Ordering::Relaxed) + size;
    let mut peak = PEAK_BYTES.load(Ordering::Relaxed);
    while live > peak {
        match PEAK_BYTES.compare_exchange_weak(peak, live, Ordering::Relaxed, Ordering::Relaxed)
        {
            Ok(_) => break,
            Err(p) => peak = p,
        }
    }
}

/// [`System`] allocator wrapper that counts allocations and bytes.
/// Deallocations are *not* subtracted from the traffic counters: those
/// measure allocation traffic (what the acceptance criterion bounds).
/// A separate live/peak gauge pair (ISSUE 10) *does* track
/// deallocations, so scale benches can report peak resident heap.
pub struct Counting;

// SAFETY: delegates verbatim to `System`; the counters are simple
// relaxed atomics with no allocation of their own.
unsafe impl GlobalAlloc for Counting {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATED_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        ALLOCATION_COUNT.fetch_add(1, Ordering::Relaxed);
        note_alloc(layout.size() as u64);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        LIVE_BYTES.fetch_sub(layout.size() as u64, Ordering::Relaxed);
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // Count only the growth: shrinks and in-place moves are not new
        // allocation traffic in any sense the benches care about.
        if new_size > layout.size() {
            ALLOCATED_BYTES.fetch_add((new_size - layout.size()) as u64, Ordering::Relaxed);
            ALLOCATION_COUNT.fetch_add(1, Ordering::Relaxed);
            note_alloc((new_size - layout.size()) as u64);
        } else if new_size < layout.size() {
            LIVE_BYTES.fetch_sub((layout.size() - new_size) as u64, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }
}

/// Total bytes requested from the allocator so far (monotonic).
pub fn allocated_bytes() -> u64 {
    ALLOCATED_BYTES.load(Ordering::Relaxed)
}

/// Total allocation calls so far (monotonic).
pub fn allocation_count() -> u64 {
    ALLOCATION_COUNT.load(Ordering::Relaxed)
}

/// Bytes currently live on the heap (allocated minus freed). Zero unless
/// [`Counting`] is installed as the global allocator.
pub fn live_bytes() -> u64 {
    LIVE_BYTES.load(Ordering::Relaxed)
}

/// High-water mark of [`live_bytes`] since process start (or the last
/// [`reset_peak`]). Zero unless [`Counting`] is installed.
pub fn peak_bytes() -> u64 {
    PEAK_BYTES.load(Ordering::Relaxed)
}

/// Rewind the peak gauge to the current live level so a bench can
/// measure the peak of one run in isolation.
pub fn reset_peak() {
    PEAK_BYTES.store(LIVE_BYTES.load(Ordering::Relaxed), Ordering::Relaxed);
}

/// Counter snapshot for before/after deltas.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AllocSnapshot {
    /// Bytes requested so far.
    pub bytes: u64,
    /// Allocation calls so far.
    pub count: u64,
}

/// Take a snapshot of both counters.
pub fn snapshot() -> AllocSnapshot {
    AllocSnapshot {
        bytes: allocated_bytes(),
        count: allocation_count(),
    }
}

/// The counter delta since `earlier`.
pub fn since(earlier: AllocSnapshot) -> AllocSnapshot {
    let now = snapshot();
    AllocSnapshot {
        bytes: now.bytes.saturating_sub(earlier.bytes),
        count: now.count.saturating_sub(earlier.count),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshots_are_monotonic_deltas() {
        // The counting allocator is not installed in test builds (the
        // counters may stay flat, or move if another build installed
        // it); either way the delta arithmetic must be monotonic and
        // never underflow.
        let a = snapshot();
        let b = snapshot();
        assert!(b.bytes >= a.bytes && b.count >= a.count);
        let d = since(a);
        assert!(d.bytes >= b.bytes - a.bytes);
        assert!(since(snapshot()).bytes <= snapshot().bytes);
    }

    #[test]
    fn peak_gauge_tracks_live_and_resets() {
        // The gauges are only driven here (the counting allocator is not
        // installed in test builds), so the arithmetic is observable.
        note_alloc(64);
        assert!(peak_bytes() >= live_bytes(), "peak can never trail live");
        LIVE_BYTES.fetch_sub(64, Ordering::Relaxed);
        reset_peak();
        assert_eq!(peak_bytes(), live_bytes(), "reset pins peak to live");
    }
}
