//! Benchmark statistics harness (criterion is unavailable offline).
//!
//! Usage pattern, shared by all `rust/benches/*` targets:
//!
//! ```ignore
//! let mut b = Bench::new("fig2_cultural");
//! let m = b.measure("axelrod F=100 n=2", Budget::default(), || run_once(...));
//! println!("{}", m);
//! ```
//!
//! Each measurement runs warmup iterations, then timed samples, and reports
//! mean ± SEM, median, and min. Timings use `std::time::Instant`
//! (CLOCK_MONOTONIC). The paper's figures average over five seeds; seed
//! variation is handled by the *callers* (each sample = one full simulation
//! instance with its own seed), matching the paper's methodology.

use std::time::{Duration, Instant};

use super::stats::Summary;

/// Sampling budget for one measurement.
#[derive(Clone, Copy, Debug)]
pub struct Budget {
    /// Warmup iterations (not recorded).
    pub warmup: usize,
    /// Recorded samples.
    pub samples: usize,
    /// Hard wall-clock cap; sampling stops early once exceeded (at least
    /// one sample is always taken).
    pub max_total: Duration,
}

impl Default for Budget {
    fn default() -> Self {
        Self {
            warmup: 1,
            samples: 5,
            max_total: Duration::from_secs(60),
        }
    }
}

impl Budget {
    /// Budget for quick smoke measurements.
    pub fn quick() -> Self {
        Self {
            warmup: 0,
            samples: 3,
            max_total: Duration::from_secs(20),
        }
    }
}

/// One finished measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    /// Label (shown in tables).
    pub label: String,
    /// Per-sample durations in seconds.
    pub samples_s: Vec<f64>,
    /// Summary over `samples_s`.
    pub summary: Summary,
}

impl std::fmt::Display for Measurement {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} mean {:>10} ± {:>8}  median {:>10}  min {:>10}  (n={})",
            self.label,
            fmt_secs(self.summary.mean),
            fmt_secs(self.summary.sem),
            fmt_secs(self.summary.median),
            fmt_secs(self.summary.min),
            self.summary.n,
        )
    }
}

/// Human-scaled duration formatting.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// A named collection of measurements (one bench target).
pub struct Bench {
    name: String,
    measurements: Vec<Measurement>,
}

impl Bench {
    /// Create a bench group.
    pub fn new(name: &str) -> Self {
        eprintln!("== bench group: {name} ==");
        Self {
            name: name.to_string(),
            measurements: Vec::new(),
        }
    }

    /// Group name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Run and record one measurement of `f` (its return value is consumed
    /// via `std::hint::black_box` to keep the optimizer honest).
    pub fn measure<T>(&mut self, label: &str, budget: Budget, mut f: impl FnMut() -> T) -> &Measurement {
        let started = Instant::now();
        for _ in 0..budget.warmup {
            std::hint::black_box(f());
            if started.elapsed() > budget.max_total {
                break;
            }
        }
        let mut samples = Vec::with_capacity(budget.samples);
        for i in 0..budget.samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
            if i + 1 < budget.samples && started.elapsed() > budget.max_total {
                break;
            }
        }
        let m = Measurement {
            label: label.to_string(),
            summary: Summary::of(&samples),
            samples_s: samples,
        };
        eprintln!("{m}");
        self.measurements.push(m);
        self.measurements.last().unwrap()
    }

    /// Record an externally-taken set of samples (seconds).
    pub fn record(&mut self, label: &str, samples_s: Vec<f64>) -> &Measurement {
        let m = Measurement {
            label: label.to_string(),
            summary: Summary::of(&samples_s),
            samples_s,
        };
        eprintln!("{m}");
        self.measurements.push(m);
        self.measurements.last().unwrap()
    }

    /// All measurements so far.
    pub fn measurements(&self) -> &[Measurement] {
        &self.measurements
    }

    /// Emit a CSV of all measurements under `target/bench-data/<name>.csv`.
    pub fn write_csv(&self) -> std::io::Result<std::path::PathBuf> {
        let mut t = super::csv::Table::new(["label", "mean_s", "sem_s", "median_s", "min_s", "n"]);
        for m in &self.measurements {
            t.push([
                m.label.clone(),
                format!("{:.9}", m.summary.mean),
                format!("{:.9}", m.summary.sem),
                format!("{:.9}", m.summary.median),
                format!("{:.9}", m.summary.min),
                m.summary.n.to_string(),
            ]);
        }
        let path = std::path::PathBuf::from(format!("target/bench-data/{}.csv", self.name));
        t.write_csv(&path)?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_records_samples() {
        let mut b = Bench::new("test_group");
        let m = b.measure(
            "noop",
            Budget {
                warmup: 1,
                samples: 4,
                max_total: Duration::from_secs(5),
            },
            || 1 + 1,
        );
        assert_eq!(m.samples_s.len(), 4);
        assert!(m.summary.mean >= 0.0);
    }

    #[test]
    fn budget_cap_stops_early() {
        let mut b = Bench::new("test_cap");
        let m = b.measure(
            "sleepy",
            Budget {
                warmup: 0,
                samples: 100,
                max_total: Duration::from_millis(30),
            },
            || std::thread::sleep(Duration::from_millis(20)),
        );
        assert!(m.samples_s.len() < 100);
    }

    #[test]
    fn fmt_secs_scales() {
        assert!(fmt_secs(2.0).ends_with(" s"));
        assert!(fmt_secs(2e-3).ends_with(" ms"));
        assert!(fmt_secs(2e-6).ends_with(" µs"));
        assert!(fmt_secs(2e-9).ends_with(" ns"));
    }
}
