//! Fixed-capacity bitsets for the SIR record's subset bookkeeping.
//!
//! The SIR model's dependence test is "does the set of subsets I have seen
//! intersect the neighbourhood mask of this subset?" — a word-wise AND over
//! two bitsets of `P = N/s` bits (≤ 400 for every paper configuration).

/// Fixed-capacity bitset over `0..capacity`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitSet {
    words: Vec<u64>,
    capacity: usize,
}

impl BitSet {
    /// All-zero bitset with room for `capacity` bits.
    pub fn new(capacity: usize) -> Self {
        Self {
            words: vec![0; capacity.div_ceil(64)],
            capacity,
        }
    }

    /// Capacity in bits.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Set bit `i`.
    #[inline]
    pub fn set(&mut self, i: usize) {
        debug_assert!(i < self.capacity);
        self.words[i / 64] |= 1u64 << (i % 64);
    }

    /// Clear bit `i`.
    #[inline]
    pub fn unset(&mut self, i: usize) {
        debug_assert!(i < self.capacity);
        self.words[i / 64] &= !(1u64 << (i % 64));
    }

    /// Test bit `i`.
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.capacity);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Zero all bits (keeps capacity; no allocation).
    #[inline]
    pub fn clear(&mut self) {
        self.words.iter_mut().for_each(|w| *w = 0);
    }

    /// Number of set bits.
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Whether `self ∩ other ≠ ∅` (word-wise AND; the record hot path).
    #[inline]
    pub fn intersects(&self, other: &BitSet) -> bool {
        debug_assert_eq!(self.capacity, other.capacity);
        self.words
            .iter()
            .zip(&other.words)
            .any(|(a, b)| a & b != 0)
    }

    /// In-place union.
    pub fn union_with(&mut self, other: &BitSet) {
        debug_assert_eq!(self.capacity, other.capacity);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
    }

    /// Iterate indices of set bits, ascending.
    pub fn iter_ones(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut w = w;
            std::iter::from_fn(move || {
                if w == 0 {
                    None
                } else {
                    let b = w.trailing_zeros() as usize;
                    w &= w - 1;
                    Some(wi * 64 + b)
                }
            })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_unset() {
        let mut b = BitSet::new(130);
        b.set(0);
        b.set(64);
        b.set(129);
        assert!(b.get(0) && b.get(64) && b.get(129));
        assert!(!b.get(1) && !b.get(128));
        assert_eq!(b.count(), 3);
        b.unset(64);
        assert!(!b.get(64));
        assert_eq!(b.count(), 2);
    }

    #[test]
    fn intersects_and_union() {
        let mut a = BitSet::new(200);
        let mut b = BitSet::new(200);
        a.set(7);
        b.set(150);
        assert!(!a.intersects(&b));
        b.set(7);
        assert!(a.intersects(&b));
        a.union_with(&b);
        assert!(a.get(150));
    }

    #[test]
    fn clear_resets() {
        let mut b = BitSet::new(100);
        for i in (0..100).step_by(3) {
            b.set(i);
        }
        b.clear();
        assert_eq!(b.count(), 0);
    }

    #[test]
    fn iter_ones_ascending() {
        let mut b = BitSet::new(256);
        for i in [3usize, 64, 65, 200, 255] {
            b.set(i);
        }
        let got: Vec<usize> = b.iter_ones().collect();
        assert_eq!(got, vec![3, 64, 65, 200, 255]);
    }
}
