//! Command-line argument parsing (hand-rolled; no `clap` offline).
//!
//! Grammar: `adapar <subcommand> [--flag] [--key value] [--key=value]
//! [positional...]`. Unknown flags are an error so typos fail fast.

use std::collections::BTreeMap;

/// Parsed arguments: subcommand, options, flags, positionals.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Args {
    /// First non-flag token, if any.
    pub subcommand: Option<String>,
    /// `--key value` / `--key=value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
    /// Remaining positional tokens.
    pub positional: Vec<String>,
}

/// Declarative spec used to validate and document a subcommand's surface.
#[derive(Clone, Debug)]
pub struct Spec {
    /// Option names accepting a value.
    pub options: &'static [&'static str],
    /// Boolean flag names.
    pub flags: &'static [&'static str],
}

/// Errors from argument parsing/validation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CliError {
    /// Option requires a value but none was supplied.
    MissingValue(String),
    /// Name not present in the spec.
    Unknown(String),
    /// Failed to parse a typed option value.
    BadValue(String, String, String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::MissingValue(name) => write!(f, "option --{name} requires a value"),
            CliError::Unknown(name) => write!(f, "unknown option --{name}"),
            CliError::BadValue(name, value, why) => {
                write!(f, "invalid value for --{name}: `{value}` ({why})")
            }
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse raw tokens (without the program name) against a spec.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, spec: &Spec) -> Result<Args, CliError> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    Self::insert(&mut out, k, Some(v.to_string()), spec)?;
                } else if spec.flags.contains(&body) {
                    out.flags.push(body.to_string());
                } else if spec.options.contains(&body) {
                    let v = it
                        .next()
                        .ok_or_else(|| CliError::MissingValue(body.to_string()))?;
                    out.options.insert(body.to_string(), v);
                } else {
                    return Err(CliError::Unknown(body.to_string()));
                }
            } else if out.subcommand.is_none() && out.options.is_empty() && out.flags.is_empty() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    fn insert(
        out: &mut Args,
        key: &str,
        value: Option<String>,
        spec: &Spec,
    ) -> Result<(), CliError> {
        if spec.options.contains(&key) {
            out.options.insert(
                key.to_string(),
                value.ok_or_else(|| CliError::MissingValue(key.to_string()))?,
            );
            Ok(())
        } else if spec.flags.contains(&key) {
            // `--flag=true/false` form
            match value.as_deref() {
                Some("true") | None => out.flags.push(key.to_string()),
                Some("false") => {}
                Some(v) => {
                    return Err(CliError::BadValue(
                        key.to_string(),
                        v.to_string(),
                        "expected true/false".into(),
                    ))
                }
            }
            Ok(())
        } else {
            Err(CliError::Unknown(key.to_string()))
        }
    }

    /// Whether a boolean flag was given.
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Raw string option.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// Typed option with default.
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v.parse::<T>().map_err(|e| {
                CliError::BadValue(name.to_string(), v.clone(), format!("{e}"))
            }),
        }
    }

    /// Comma-separated list option, e.g. `--sizes 10,20,50`.
    pub fn get_list<T: std::str::FromStr>(
        &self,
        name: &str,
        default: &[T],
    ) -> Result<Vec<T>, CliError>
    where
        T: Clone,
        T::Err: std::fmt::Display,
    {
        match self.options.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .filter(|s| !s.trim().is_empty())
                .map(|s| {
                    s.trim().parse::<T>().map_err(|e| {
                        CliError::BadValue(name.to_string(), s.to_string(), format!("{e}"))
                    })
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPEC: Spec = Spec {
        options: &["model", "workers", "sizes"],
        flags: &["paper-scale", "quiet"],
    };

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = Args::parse(toks("sweep --model axelrod --workers=3 --paper-scale pos1"), &SPEC)
            .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("sweep"));
        assert_eq!(a.get("model"), Some("axelrod"));
        assert_eq!(a.get_parse::<usize>("workers", 1).unwrap(), 3);
        assert!(a.has_flag("paper-scale"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn unknown_option_fails() {
        assert_eq!(
            Args::parse(toks("run --nope 1"), &SPEC),
            Err(CliError::Unknown("nope".into()))
        );
    }

    #[test]
    fn missing_value_fails() {
        assert_eq!(
            Args::parse(toks("run --model"), &SPEC),
            Err(CliError::MissingValue("model".into()))
        );
    }

    #[test]
    fn list_option() {
        let a = Args::parse(toks("x --sizes 10,20,50"), &SPEC).unwrap();
        assert_eq!(a.get_list::<u32>("sizes", &[]).unwrap(), vec![10, 20, 50]);
        let d = Args::parse(toks("x"), &SPEC).unwrap();
        assert_eq!(d.get_list::<u32>("sizes", &[7]).unwrap(), vec![7]);
    }

    #[test]
    fn bad_typed_value() {
        let a = Args::parse(toks("x --workers abc"), &SPEC).unwrap();
        assert!(matches!(
            a.get_parse::<usize>("workers", 1),
            Err(CliError::BadValue(..))
        ));
    }
}
