//! CSV and markdown table emitters for figure/benchmark data.
//!
//! Every bench target emits both a human-readable markdown table (stdout)
//! and a machine-readable CSV under `target/bench-data/` so figures can be
//! re-plotted without re-running the sweep.

use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::Path;

/// An in-memory rectangular table with a header row.
#[derive(Clone, Debug)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Create a table with the given column names.
    pub fn new<S: Into<String>, I: IntoIterator<Item = S>>(header: I) -> Self {
        Self {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    /// Number of columns.
    pub fn width(&self) -> usize {
        self.header.len()
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// Whether the table has no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Append a row; panics if the arity does not match the header.
    pub fn push<S: Into<String>, I: IntoIterator<Item = S>>(&mut self, row: I) {
        let row: Vec<String> = row.into_iter().map(Into::into).collect();
        assert_eq!(
            row.len(),
            self.header.len(),
            "row arity {} != header arity {}",
            row.len(),
            self.header.len()
        );
        self.rows.push(row);
    }

    /// Render as CSV (RFC-4180 quoting for fields containing `,"\n`).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let emit = |out: &mut String, fields: &[String]| {
            let mut first = true;
            for f in fields {
                if !first {
                    out.push(',');
                }
                first = false;
                if f.contains(',') || f.contains('"') || f.contains('\n') {
                    out.push('"');
                    out.push_str(&f.replace('"', "\"\""));
                    out.push('"');
                } else {
                    out.push_str(f);
                }
            }
            out.push('\n');
        };
        emit(&mut out, &self.header);
        for r in &self.rows {
            emit(&mut out, r);
        }
        out
    }

    /// Render as a GitHub-flavoured markdown table with aligned columns.
    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, f) in r.iter().enumerate() {
                widths[i] = widths[i].max(f.len());
            }
        }
        let mut out = String::new();
        let emit = |out: &mut String, fields: &[String], widths: &[usize]| {
            out.push('|');
            for (f, w) in fields.iter().zip(widths) {
                let _ = write!(out, " {f:<w$} |");
            }
            out.push('\n');
        };
        emit(&mut out, &self.header, &widths);
        out.push('|');
        for w in &widths {
            let _ = write!(out, "{:-<1$}|", "", w + 2);
        }
        out.push('\n');
        for r in &self.rows {
            emit(&mut out, r, &widths);
        }
        out
    }

    /// Write the CSV rendering to `path`, creating parent directories.
    pub fn write_csv<P: AsRef<Path>>(&self, path: P) -> io::Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            fs::create_dir_all(parent)?;
        }
        fs::write(path, self.to_csv())
    }
}

/// Parse a CSV string produced by [`Table::to_csv`] back into a table.
/// Supports RFC-4180 quoting; used by tests and by report tooling.
pub fn parse_csv(text: &str) -> Option<Table> {
    let mut records: Vec<Vec<String>> = Vec::new();
    let mut field = String::new();
    let mut record: Vec<String> = Vec::new();
    let mut in_quotes = false;
    let mut chars = text.chars().peekable();
    while let Some(c) = chars.next() {
        if in_quotes {
            match c {
                '"' => {
                    if chars.peek() == Some(&'"') {
                        chars.next();
                        field.push('"');
                    } else {
                        in_quotes = false;
                    }
                }
                _ => field.push(c),
            }
        } else {
            match c {
                '"' => in_quotes = true,
                ',' => {
                    record.push(std::mem::take(&mut field));
                }
                '\n' => {
                    record.push(std::mem::take(&mut field));
                    records.push(std::mem::take(&mut record));
                }
                '\r' => {}
                _ => field.push(c),
            }
        }
    }
    if !field.is_empty() || !record.is_empty() {
        record.push(field);
        records.push(record);
    }
    let mut it = records.into_iter();
    let header = it.next()?;
    let mut t = Table::new(header);
    for r in it {
        if r.len() == t.width() {
            t.push(r);
        } else {
            return None;
        }
    }
    Some(t)
}

impl Table {
    /// Access the header.
    pub fn header(&self) -> &[String] {
        &self.header
    }

    /// Access the rows.
    pub fn rows(&self) -> &[Vec<String>] {
        &self.rows
    }

    /// Column index by name.
    pub fn col(&self, name: &str) -> Option<usize> {
        self.header.iter().position(|h| h == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip_plain() {
        let mut t = Table::new(["a", "b"]);
        t.push(["1", "2"]);
        t.push(["x", "y"]);
        let parsed = parse_csv(&t.to_csv()).unwrap();
        assert_eq!(parsed.header(), t.header());
        assert_eq!(parsed.rows(), t.rows());
    }

    #[test]
    fn csv_roundtrip_quoted() {
        let mut t = Table::new(["a", "b"]);
        t.push(["with,comma", "with\"quote"]);
        t.push(["multi\nline", "ok"]);
        let parsed = parse_csv(&t.to_csv()).unwrap();
        assert_eq!(parsed.rows(), t.rows());
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new(["a", "b"]);
        t.push(["only-one"]);
    }

    #[test]
    fn markdown_has_separator_and_rows() {
        let mut t = Table::new(["name", "value"]);
        t.push(["x", "1"]);
        let md = t.to_markdown();
        let lines: Vec<&str> = md.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[1].starts_with("|--") || lines[1].starts_with("| --"));
    }

    #[test]
    fn col_lookup() {
        let t = Table::new(["x", "y", "z"]);
        assert_eq!(t.col("y"), Some(1));
        assert_eq!(t.col("w"), None);
    }
}
