//! Log-bucketed histograms for latency / duration distributions.
//!
//! Buckets grow geometrically (factor 2 by default over nanoseconds), which
//! keeps relative error bounded across the nine orders of magnitude between
//! a lock acquisition and a full simulation run.

/// A histogram with geometric (power-of-two) buckets over `u64` values.
#[derive(Clone, Debug)]
pub struct LogHistogram {
    /// counts[i] counts values v with 2^i <= v < 2^(i+1); counts[0] also
    /// includes v == 0.
    counts: [u64; 64],
    total: u64,
    sum: u128,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Self {
            counts: [0; 64],
            total: 0,
            sum: 0,
        }
    }

    #[inline]
    fn bucket(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            63 - v.leading_zeros() as usize
        }
    }

    /// Record one value.
    #[inline]
    pub fn record(&mut self, v: u64) {
        self.counts[Self::bucket(v)] += 1;
        self.total += 1;
        self.sum += v as u128;
    }

    /// Number of recorded values.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Mean of recorded values (0 if empty).
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    /// Approximate quantile: upper edge of the bucket containing quantile
    /// `q` (in `[0,1]`). Within a factor of 2 of the true value.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0)) * self.total as f64).ceil() as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target.max(1) {
                return if i >= 63 { u64::MAX } else { 1u64 << (i + 1) };
            }
        }
        u64::MAX
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &LogHistogram) {
        for i in 0..64 {
            self.counts[i] += other.counts[i];
        }
        self.total += other.total;
        self.sum += other.sum;
    }

    /// Non-empty buckets as `(lower_edge, count)` pairs, ascending.
    pub fn buckets(&self) -> Vec<(u64, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (if i == 0 { 0 } else { 1u64 << i }, c))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_edges() {
        assert_eq!(LogHistogram::bucket(0), 0);
        assert_eq!(LogHistogram::bucket(1), 0);
        assert_eq!(LogHistogram::bucket(2), 1);
        assert_eq!(LogHistogram::bucket(3), 1);
        assert_eq!(LogHistogram::bucket(4), 2);
        assert_eq!(LogHistogram::bucket(u64::MAX), 63);
    }

    #[test]
    fn mean_and_count() {
        let mut h = LogHistogram::new();
        for v in [1u64, 2, 3, 4] {
            h.record(v);
        }
        assert_eq!(h.count(), 4);
        assert!((h.mean() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn quantile_monotone() {
        let mut h = LogHistogram::new();
        for v in 0..1000u64 {
            h.record(v);
        }
        assert!(h.quantile(0.1) <= h.quantile(0.5));
        assert!(h.quantile(0.5) <= h.quantile(0.99));
    }

    #[test]
    fn merge_adds_counts() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        a.record(10);
        b.record(1000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.buckets().len(), 2);
    }
}
