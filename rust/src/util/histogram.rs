//! Log-bucketed histograms for latency / duration distributions.
//!
//! Buckets grow geometrically (factor 2 by default over nanoseconds), which
//! keeps relative error bounded across the nine orders of magnitude between
//! a lock acquisition and a full simulation run.

/// A histogram with geometric (power-of-two) buckets over `u64` values.
///
/// Merging is associative and commutative (bucket-wise saturating
/// addition), so per-worker histograms drained by the telemetry
/// aggregator can be folded in any order — the merged result is
/// independent of drain interleaving.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LogHistogram {
    /// counts[i] counts values v with 2^i <= v < 2^(i+1); counts[0] also
    /// includes v == 0.
    counts: [u64; 64],
    total: u64,
    sum: u128,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Self {
            counts: [0; 64],
            total: 0,
            sum: 0,
        }
    }

    #[inline]
    fn bucket(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            63 - v.leading_zeros() as usize
        }
    }

    /// Record one value.
    #[inline]
    pub fn record(&mut self, v: u64) {
        self.record_many(v, 1);
    }

    /// Record `n` occurrences of `v` at once (the aggregator's folding
    /// path). Counts saturate at `u64::MAX` instead of wrapping, so a
    /// pathological merge chain degrades to a pinned count rather than
    /// silently losing 2^64 samples.
    #[inline]
    pub fn record_many(&mut self, v: u64, n: u64) {
        let b = Self::bucket(v);
        self.counts[b] = self.counts[b].saturating_add(n);
        self.total = self.total.saturating_add(n);
        self.sum = self.sum.saturating_add(v as u128 * n as u128);
    }

    /// Number of recorded values.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Whether nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Mean of recorded values (0 if empty).
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    /// Approximate quantile: upper edge of the bucket containing quantile
    /// `q` (in `[0,1]`). Within a factor of 2 of the true value.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0)) * self.total as f64).ceil() as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target.max(1) {
                return if i >= 63 { u64::MAX } else { 1u64 << (i + 1) };
            }
        }
        u64::MAX
    }

    /// Median (upper bucket edge, like [`LogHistogram::quantile`]).
    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    /// 90th percentile (upper bucket edge).
    pub fn p90(&self) -> u64 {
        self.quantile(0.90)
    }

    /// 99th percentile (upper bucket edge).
    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// Merge another histogram into this one (saturating, associative,
    /// commutative).
    pub fn merge(&mut self, other: &LogHistogram) {
        for i in 0..64 {
            self.counts[i] = self.counts[i].saturating_add(other.counts[i]);
        }
        self.total = self.total.saturating_add(other.total);
        self.sum = self.sum.saturating_add(other.sum);
    }

    /// Non-empty buckets as `(lower_edge, count)` pairs, ascending.
    pub fn buckets(&self) -> Vec<(u64, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (if i == 0 { 0 } else { 1u64 << i }, c))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_edges() {
        assert_eq!(LogHistogram::bucket(0), 0);
        assert_eq!(LogHistogram::bucket(1), 0);
        assert_eq!(LogHistogram::bucket(2), 1);
        assert_eq!(LogHistogram::bucket(3), 1);
        assert_eq!(LogHistogram::bucket(4), 2);
        assert_eq!(LogHistogram::bucket(u64::MAX), 63);
    }

    #[test]
    fn mean_and_count() {
        let mut h = LogHistogram::new();
        for v in [1u64, 2, 3, 4] {
            h.record(v);
        }
        assert_eq!(h.count(), 4);
        assert!((h.mean() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn quantile_monotone() {
        let mut h = LogHistogram::new();
        for v in 0..1000u64 {
            h.record(v);
        }
        assert!(h.quantile(0.1) <= h.quantile(0.5));
        assert!(h.quantile(0.5) <= h.quantile(0.99));
    }

    #[test]
    fn merge_adds_counts() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        a.record(10);
        b.record(1000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.buckets().len(), 2);
    }

    #[test]
    fn merge_is_associative_and_commutative() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        let mut c = LogHistogram::new();
        for v in [0u64, 1, 7, 63] {
            a.record(v);
        }
        for v in [64u64, 65, 4096] {
            b.record(v);
        }
        c.record_many(u64::MAX, 3);

        // (a ⊕ b) ⊕ c
        let mut left = a.clone();
        left.merge(&b);
        left.merge(&c);
        // a ⊕ (b ⊕ c)
        let mut bc = b.clone();
        bc.merge(&c);
        let mut right = a.clone();
        right.merge(&bc);
        assert_eq!(left, right, "merge must be associative");

        // b ⊕ a == a ⊕ b
        let mut ba = b.clone();
        ba.merge(&a);
        let mut ab = a.clone();
        ab.merge(&b);
        assert_eq!(ab, ba, "merge must be commutative");
    }

    #[test]
    fn bucket_boundaries_split_powers_of_two() {
        // Exactly at a power of two a value starts a new bucket; one
        // below it stays in the previous bucket.
        for k in 1..63usize {
            let edge = 1u64 << k;
            assert_eq!(LogHistogram::bucket(edge), k, "2^{k} opens bucket {k}");
            assert_eq!(
                LogHistogram::bucket(edge - 1),
                k - 1,
                "2^{k}-1 stays in bucket {}",
                k - 1
            );
        }
        let mut h = LogHistogram::new();
        h.record(64); // bucket 6: [64, 128)
        assert_eq!(h.buckets(), vec![(64, 1)]);
        assert_eq!(h.quantile(1.0), 128, "upper edge of [64,128)");
        let mut top = LogHistogram::new();
        top.record(u64::MAX); // bucket 63 has no finite upper edge
        assert_eq!(top.quantile(1.0), u64::MAX);
    }

    #[test]
    fn counts_saturate_instead_of_wrapping() {
        let mut h = LogHistogram::new();
        h.record_many(8, u64::MAX);
        h.record_many(8, 5); // would wrap without saturation
        assert_eq!(h.count(), u64::MAX);
        assert_eq!(h.buckets(), vec![(8, u64::MAX)]);

        let mut other = LogHistogram::new();
        other.record_many(8, u64::MAX);
        h.merge(&other);
        assert_eq!(h.count(), u64::MAX, "merge saturates too");
        // Percentiles stay sane at the saturation point.
        assert_eq!(h.p50(), 16);
        assert_eq!(h.p99(), 16);
    }

    #[test]
    fn percentile_shorthands_match_quantile() {
        let mut h = LogHistogram::new();
        for v in 0..1000u64 {
            h.record(v);
        }
        assert_eq!(h.p50(), h.quantile(0.50));
        assert_eq!(h.p90(), h.quantile(0.90));
        assert_eq!(h.p99(), h.quantile(0.99));
        assert!(h.p50() <= h.p90() && h.p90() <= h.p99());
    }
}
