//! A minimal JSON value + emitter + parser (the crate registry is
//! offline, so no `serde_json`). The CLI's `--json` output, the
//! observation JSON-lines sink and the `BENCH_*.json` perf artifacts all
//! build a [`Json`] tree and render it; the perf ledger (`cli
//! perf-diff`) reads committed baselines and fresh bench artifacts back
//! through [`Json::parse`].

use std::fmt::Write as _;

/// A JSON value. Object fields keep insertion order (stable output).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null` (also the rendering of non-finite floats).
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// An integer.
    Int(i64),
    /// A finite float (non-finite values render as `null`).
    Float(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, in field order.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Render to a compact JSON string.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Float(x) => {
                if x.is_finite() {
                    // Rust's shortest-roundtrip `Display` is valid JSON
                    // for finite values (no exponent, `-0` handled).
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (key, value)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, key);
                    out.push(':');
                    value.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl Json {
    /// Parse a JSON document (the full input must be one value, with
    /// only whitespace around it). Numbers without `.`/exponent that fit
    /// an `i64` parse as [`Json::Int`]; everything else numeric parses
    /// as [`Json::Float`].
    pub fn parse(input: &str) -> Result<Json, String> {
        let bytes = input.as_bytes();
        let mut p = Parser { bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }

    /// Object field lookup (`None` for missing keys or non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The object's fields, if this is an object.
    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(fields) => Some(fields),
            _ => None,
        }
    }

    /// The array's items, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// String payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Integer payload (also accepts floats with an exact integer value).
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            Json::Float(x) if x.fract() == 0.0 && x.abs() < i64::MAX as f64 => Some(*x as i64),
            _ => None,
        }
    }

    /// Numeric payload as a float.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(i) => Some(*i as f64),
            Json::Float(x) => Some(*x),
            _ => None,
        }
    }
}

/// Recursive-descent JSON parser over raw bytes (ASCII structure; UTF-8
/// payloads pass through string parsing untouched).
struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected `{}` at byte {}",
                b as char, self.pos
            ))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(format!("unexpected `{}` at byte {}", c as char, self.pos)),
            None => Err("unexpected end of input".to_string()),
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected `,` or `]` at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            fields.push((key, self.value()?));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(format!("expected `,` or `}}` at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".to_string()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // Surrogate pairs (the emitter never writes
                            // them, but accept foreign files).
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let combined = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo.wrapping_sub(0xDC00) & 0x3FF);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(c.ok_or_else(|| {
                                format!("invalid \\u escape at byte {}", self.pos)
                            })?);
                        }
                        _ => return Err(format!("bad escape at byte {}", self.pos)),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (multi-byte chars pass
                    // through unchanged).
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| format!("invalid UTF-8 at byte {}", self.pos))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let end = self.pos + 4;
        if end > self.bytes.len() {
            return Err("truncated \\u escape".to_string());
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..end])
            .map_err(|_| "non-ASCII \\u escape".to_string())?;
        let v = u32::from_str_radix(s, 16)
            .map_err(|_| format!("bad \\u escape at byte {}", self.pos))?;
        self.pos = end;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let mut float = false;
        while let Some(c) = self.peek() {
            match c {
                b'0'..=b'9' => self.pos += 1,
                b'.' | b'e' | b'E' | b'+' | b'-' => {
                    float = true;
                    self.pos += 1;
                }
                _ => break,
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| "invalid number".to_string())?;
        if !float {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Json::Int(i));
            }
        }
        text.parse::<f64>()
            .map(Json::Float)
            .map_err(|_| format!("invalid number `{text}` at byte {start}"))
    }
}

impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.render())
    }
}

impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}

impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Int(v)
    }
}

impl From<u64> for Json {
    fn from(v: u64) -> Self {
        if v <= i64::MAX as u64 {
            Json::Int(v as i64)
        } else {
            Json::Float(v as f64)
        }
    }
}

impl From<u32> for Json {
    fn from(v: u32) -> Self {
        Json::Int(v as i64)
    }
}

impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::from(v as u64)
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Float(v)
    }
}

impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}

impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_render() {
        assert_eq!(Json::Null.render(), "null");
        assert_eq!(Json::from(true).render(), "true");
        assert_eq!(Json::from(-7i64).render(), "-7");
        assert_eq!(Json::from(1.5).render(), "1.5");
        assert_eq!(Json::from(1.0).render(), "1");
        assert_eq!(Json::from(f64::NAN).render(), "null");
        assert_eq!(Json::from(f64::INFINITY).render(), "null");
        assert_eq!(Json::from(u64::MAX).render(), format!("{}", u64::MAX as f64));
    }

    #[test]
    fn strings_escape() {
        assert_eq!(Json::from("plain").render(), r#""plain""#);
        assert_eq!(
            Json::from("a\"b\\c\nd\te\u{1}").render(),
            r#""a\"b\\c\nd\te\u0001""#
        );
    }

    #[test]
    fn nesting() {
        let j = Json::Obj(vec![
            ("xs".into(), Json::Arr(vec![Json::from(1i64), Json::Null])),
            (
                "inner".into(),
                Json::Obj(vec![("k".into(), Json::from("v"))]),
            ),
        ]);
        assert_eq!(j.render(), r#"{"xs":[1,null],"inner":{"k":"v"}}"#);
        assert_eq!(j.to_string(), j.render());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::Arr(vec![]).render(), "[]");
        assert_eq!(Json::Obj(vec![]).render(), "{}");
    }

    #[test]
    fn parse_round_trips_rendered_trees() {
        let j = Json::Obj(vec![
            ("xs".into(), Json::Arr(vec![Json::from(1i64), Json::Null])),
            ("neg".into(), Json::from(-42i64)),
            ("f".into(), Json::from(2.75)),
            ("s".into(), Json::from("a\"b\\c\nd\te")),
            ("t".into(), Json::from(true)),
            (
                "inner".into(),
                Json::Obj(vec![("k".into(), Json::from("v"))]),
            ),
        ]);
        assert_eq!(Json::parse(&j.render()).unwrap(), j);
    }

    #[test]
    fn parse_accepts_whitespace_and_unicode() {
        let j = Json::parse(" { \"k\" : [ 1 ,\n 2.5 , \"π\\u00e9\" ] } ").unwrap();
        assert_eq!(
            j.get("k").unwrap().as_arr().unwrap(),
            &[Json::Int(1), Json::Float(2.5), Json::Str("πé".into())]
        );
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn accessors() {
        let j = Json::parse(r#"{"a":1,"b":2.0,"c":"x","d":[3]}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_i64(), Some(1));
        assert_eq!(j.get("b").unwrap().as_i64(), Some(2), "integral float");
        assert_eq!(j.get("b").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("c").unwrap().as_str(), Some("x"));
        assert_eq!(j.get("d").unwrap().as_arr().unwrap().len(), 1);
        assert!(j.get("missing").is_none());
        assert_eq!(j.as_obj().unwrap().len(), 4);
        assert!(Json::Null.get("a").is_none());
    }
}
