//! A minimal JSON value + emitter (the crate registry is offline, so no
//! `serde_json`). Emission only — the CLI's `--json` output, the
//! observation JSON-lines sink and the `BENCH_*.json` perf artifacts all
//! build a [`Json`] tree and render it; nothing in the crate parses JSON.

use std::fmt::Write as _;

/// A JSON value. Object fields keep insertion order (stable output).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null` (also the rendering of non-finite floats).
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// An integer.
    Int(i64),
    /// A finite float (non-finite values render as `null`).
    Float(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, in field order.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Render to a compact JSON string.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Float(x) => {
                if x.is_finite() {
                    // Rust's shortest-roundtrip `Display` is valid JSON
                    // for finite values (no exponent, `-0` handled).
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (key, value)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, key);
                    out.push(':');
                    value.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.render())
    }
}

impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}

impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Int(v)
    }
}

impl From<u64> for Json {
    fn from(v: u64) -> Self {
        if v <= i64::MAX as u64 {
            Json::Int(v as i64)
        } else {
            Json::Float(v as f64)
        }
    }
}

impl From<u32> for Json {
    fn from(v: u32) -> Self {
        Json::Int(v as i64)
    }
}

impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::from(v as u64)
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Float(v)
    }
}

impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}

impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_render() {
        assert_eq!(Json::Null.render(), "null");
        assert_eq!(Json::from(true).render(), "true");
        assert_eq!(Json::from(-7i64).render(), "-7");
        assert_eq!(Json::from(1.5).render(), "1.5");
        assert_eq!(Json::from(1.0).render(), "1");
        assert_eq!(Json::from(f64::NAN).render(), "null");
        assert_eq!(Json::from(f64::INFINITY).render(), "null");
        assert_eq!(Json::from(u64::MAX).render(), format!("{}", u64::MAX as f64));
    }

    #[test]
    fn strings_escape() {
        assert_eq!(Json::from("plain").render(), r#""plain""#);
        assert_eq!(
            Json::from("a\"b\\c\nd\te\u{1}").render(),
            r#""a\"b\\c\nd\te\u0001""#
        );
    }

    #[test]
    fn nesting() {
        let j = Json::Obj(vec![
            ("xs".into(), Json::Arr(vec![Json::from(1i64), Json::Null])),
            (
                "inner".into(),
                Json::Obj(vec![("k".into(), Json::from("v"))]),
            ),
        ]);
        assert_eq!(j.render(), r#"{"xs":[1,null],"inner":{"k":"v"}}"#);
        assert_eq!(j.to_string(), j.render());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::Arr(vec![]).render(), "[]");
        assert_eq!(Json::Obj(vec![]).render(), "{}");
    }
}
