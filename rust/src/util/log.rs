//! Minimal leveled logging to stderr, controlled by the `ADAPAR_LOG`
//! environment variable (`error`, `warn`, `info` (default), `debug`,
//! `trace`).

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, ascending verbosity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Unrecoverable or surprising conditions.
    Error = 0,
    /// Suspicious but tolerated conditions.
    Warn = 1,
    /// High-level progress (default).
    Info = 2,
    /// Per-phase details.
    Debug = 3,
    /// Per-task details (very chatty).
    Trace = 4,
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // u8::MAX = uninitialized

fn init_from_env() -> u8 {
    let lvl = match std::env::var("ADAPAR_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    } as u8;
    MAX_LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// Whether messages at `level` are currently emitted.
#[inline]
pub fn enabled(level: Level) -> bool {
    let mut max = MAX_LEVEL.load(Ordering::Relaxed);
    if max == u8::MAX {
        max = init_from_env();
    }
    (level as u8) <= max
}

/// Emit a message (used by the macros; prefer those).
pub fn emit(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[adapar {tag}] {args}");
    }
}

/// Log at error level.
#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Error, format_args!($($t)*)) } }
/// Log at warn level.
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Warn, format_args!($($t)*)) } }
/// Log at info level.
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Info, format_args!($($t)*)) } }
/// Log at debug level.
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Debug, format_args!($($t)*)) } }
/// Log at trace level.
#[macro_export]
macro_rules! log_trace { ($($t:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Trace, format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order() {
        assert!(Level::Error < Level::Info);
        assert!(Level::Info < Level::Trace);
    }
}
