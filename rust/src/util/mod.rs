//! Hand-rolled utility substrates.
//!
//! The crate registry is unreachable in this build environment (DESIGN.md
//! §2), so the functionality normally pulled from `clap`, `criterion`,
//! `serde`+`toml`, and `proptest` is implemented here from scratch:
//!
//! * [`cli`] — command-line argument parsing.
//! * [`bench`] — benchmark statistics harness (warmup, timed samples,
//!   robust summary statistics) used by all `cargo bench` targets.
//! * [`toml`] — a TOML-subset parser for the config system.
//! * [`prop`] — a property-based testing mini-framework with shrinking.
//! * [`stats`] — online and batch statistics (Welford, SEM, percentiles).
//! * [`histogram`] — log-bucketed latency histograms.
//! * [`csv`] — CSV/markdown table emitters for figure data.
//! * [`log`] — leveled stderr logging controlled by `ADAPAR_LOG`.

pub mod bench;
pub mod bitset;
pub mod cli;
pub mod csv;
pub mod histogram;
pub mod log;
pub mod prop;
pub mod stats;
pub mod toml;
pub mod u32set;
