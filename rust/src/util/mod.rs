//! Hand-rolled utility substrates.
//!
//! The crate registry is unreachable in this build environment (DESIGN.md
//! §2), so the functionality normally pulled from `clap`, `criterion`,
//! `serde`+`toml`, and `proptest` is implemented here from scratch:
//!
//! * [`cli`] — command-line argument parsing.
//! * [`bench`] — benchmark statistics harness (warmup, timed samples,
//!   robust summary statistics) used by all `cargo bench` targets.
//! * [`toml`] — a TOML-subset parser for the config system.
//! * [`prop`] — a property-based testing mini-framework with shrinking.
//! * [`stats`] — online and batch statistics (Welford, SEM, percentiles).
//! * [`histogram`] — log-bucketed latency histograms.
//! * [`csv`] — CSV/markdown table emitters for figure data.
//! * [`json`] — a minimal JSON value/emitter for `--json` output, the
//!   observation JSON-lines sink, and `BENCH_*.json` perf artifacts.
//! * [`log`] — leveled stderr logging controlled by `ADAPAR_LOG`.

/// Create `path`'s parent directories if it has any (no-op for bare
/// file names). Shared by every artifact writer (observation sinks,
/// sweep reports, bench JSON).
pub fn create_parent_dirs(path: &std::path::Path) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    Ok(())
}

pub mod alloc;
pub mod bench;
pub mod bitset;
pub mod cli;
pub mod csv;
pub mod histogram;
pub mod json;
pub mod log;
pub mod prop;
pub mod stats;
pub mod toml;
pub mod u32set;
