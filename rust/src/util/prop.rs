//! Property-based testing mini-framework (proptest is unavailable offline).
//!
//! A [`Gen`] produces random values and proposes *shrinks* (simpler
//! candidate values) for failing inputs. [`check`] runs a property over many
//! generated cases, and on failure greedily shrinks to a (locally) minimal
//! counterexample before panicking with a reproducible report.
//!
//! ```ignore
//! use adapar::util::prop::{check, Config, ranged_usize, vec_of};
//! check("sorted idempotent", Config::default(), vec_of(ranged_usize(0, 100), 0, 32), |v| {
//!     let mut a = v.clone(); a.sort();
//!     let mut b = a.clone(); b.sort();
//!     a == b
//! });
//! ```

use crate::sim::rng::Rng;

/// A generator of random test cases with shrinking.
pub trait Gen {
    /// Generated value type.
    type Value: Clone + std::fmt::Debug;
    /// Generate one value.
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Propose strictly-simpler candidates for `v` (may be empty).
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value>;
}

/// Runner configuration.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Number of random cases.
    pub cases: usize,
    /// Base seed (each case derives case-seed `seed + i`).
    pub seed: u64,
    /// Maximum shrink iterations on failure.
    pub max_shrink: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            cases: 64,
            seed: 0xADA9_A875,
            max_shrink: 400,
        }
    }
}

/// Run `prop` over `cfg.cases` generated values; panic with a shrunk
/// counterexample on failure.
pub fn check<G: Gen>(name: &str, cfg: Config, gen: G, prop: impl Fn(&G::Value) -> bool) {
    for case in 0..cfg.cases {
        let mut rng = Rng::stream(cfg.seed, case as u64);
        let value = gen.generate(&mut rng);
        if !prop(&value) {
            let minimal = shrink_loop(&gen, value, &prop, cfg.max_shrink);
            panic!(
                "property `{name}` failed (case {case}, seed {seed}); minimal counterexample: {minimal:?}",
                seed = cfg.seed,
            );
        }
    }
}

fn shrink_loop<G: Gen>(
    gen: &G,
    mut failing: G::Value,
    prop: &impl Fn(&G::Value) -> bool,
    max_iters: usize,
) -> G::Value {
    let mut iters = 0;
    'outer: while iters < max_iters {
        for cand in gen.shrink(&failing) {
            iters += 1;
            if !prop(&cand) {
                failing = cand;
                continue 'outer;
            }
            if iters >= max_iters {
                break;
            }
        }
        break;
    }
    failing
}

// ---------------------------------------------------------------------------
// Built-in generators
// ---------------------------------------------------------------------------

/// Uniform `usize` in `[lo, hi]`, shrinking toward `lo`.
pub struct RangedUsize {
    lo: usize,
    hi: usize,
}

/// Construct a [`RangedUsize`].
pub fn ranged_usize(lo: usize, hi: usize) -> RangedUsize {
    assert!(lo <= hi);
    RangedUsize { lo, hi }
}

impl Gen for RangedUsize {
    type Value = usize;
    fn generate(&self, rng: &mut Rng) -> usize {
        self.lo + rng.index(self.hi - self.lo + 1)
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (*v - self.lo) / 2);
            out.push(*v - 1);
            out.dedup();
            out.retain(|x| x < v);
        }
        out
    }
}

/// Uniform `u64` seed values, shrinking toward small numbers.
pub struct AnySeed;

impl Gen for AnySeed {
    type Value = u64;
    fn generate(&self, rng: &mut Rng) -> u64 {
        rng.next_u64()
    }
    fn shrink(&self, v: &u64) -> Vec<u64> {
        if *v == 0 {
            vec![]
        } else {
            vec![0, *v >> 1, *v >> 8]
                .into_iter()
                .filter(|x| x < v)
                .collect()
        }
    }
}

/// Uniform `f64` in `[lo, hi)`, shrinking toward `lo`.
pub struct RangedF64 {
    lo: f64,
    hi: f64,
}

/// Construct a [`RangedF64`].
pub fn ranged_f64(lo: f64, hi: f64) -> RangedF64 {
    assert!(lo < hi);
    RangedF64 { lo, hi }
}

impl Gen for RangedF64 {
    type Value = f64;
    fn generate(&self, rng: &mut Rng) -> f64 {
        self.lo + rng.unit_f64() * (self.hi - self.lo)
    }
    fn shrink(&self, v: &f64) -> Vec<f64> {
        let mid = self.lo + (*v - self.lo) / 2.0;
        if *v > self.lo && mid < *v {
            vec![self.lo, mid]
        } else {
            vec![]
        }
    }
}

/// Vector of values from an element generator, with length in `[min, max]`.
/// Shrinks by removing chunks and by shrinking single elements.
pub struct VecOf<G> {
    elem: G,
    min: usize,
    max: usize,
}

/// Construct a [`VecOf`].
pub fn vec_of<G: Gen>(elem: G, min: usize, max: usize) -> VecOf<G> {
    assert!(min <= max);
    VecOf { elem, min, max }
}

impl<G: Gen> Gen for VecOf<G> {
    type Value = Vec<G::Value>;
    fn generate(&self, rng: &mut Rng) -> Vec<G::Value> {
        let len = self.min + rng.index(self.max - self.min + 1);
        (0..len).map(|_| self.elem.generate(rng)).collect()
    }
    fn shrink(&self, v: &Vec<G::Value>) -> Vec<Vec<G::Value>> {
        let mut out = Vec::new();
        // Remove halves, then single elements.
        if v.len() > self.min {
            let half = v.len() / 2;
            if half >= self.min {
                out.push(v[..half].to_vec());
                out.push(v[half..].to_vec());
            }
            for i in 0..v.len().min(8) {
                let mut c = v.clone();
                c.remove(i);
                if c.len() >= self.min {
                    out.push(c);
                }
            }
        }
        // Shrink one element at a time (first few positions).
        for i in 0..v.len().min(4) {
            for e in self.elem.shrink(&v[i]) {
                let mut c = v.clone();
                c[i] = e;
                out.push(c);
            }
        }
        out
    }
}

/// Pair of independent generators.
pub struct PairOf<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for PairOf<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("reverse twice", Config::default(), vec_of(ranged_usize(0, 9), 0, 16), |v| {
            let mut r = v.clone();
            r.reverse();
            r.reverse();
            &r == v
        });
    }

    #[test]
    fn failing_property_shrinks_small() {
        let result = std::panic::catch_unwind(|| {
            check(
                "no vec contains 7",
                Config { cases: 200, ..Config::default() },
                vec_of(ranged_usize(0, 9), 0, 16),
                |v| !v.contains(&7),
            );
        });
        let err = result.expect_err("property should fail");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        // The minimal counterexample should be exactly [7].
        assert!(msg.contains("[7]"), "got: {msg}");
    }

    #[test]
    fn ranged_usize_respects_bounds() {
        let g = ranged_usize(5, 10);
        let mut rng = Rng::new(0);
        for _ in 0..200 {
            let v = g.generate(&mut rng);
            assert!((5..=10).contains(&v));
            for s in g.shrink(&v) {
                assert!(s < v && s >= 5);
            }
        }
    }

    #[test]
    fn pair_shrinks_componentwise() {
        let g = PairOf(ranged_usize(0, 10), ranged_usize(0, 10));
        let shrinks = g.shrink(&(4, 6));
        assert!(shrinks.iter().any(|&(a, b)| a < 4 && b == 6));
        assert!(shrinks.iter().any(|&(a, b)| a == 4 && b < 6));
    }
}
