//! Statistics helpers: online (Welford) accumulation and batch summaries.

/// Online mean/variance accumulator (Welford's algorithm).
#[derive(Clone, Debug, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Online {
    /// Empty accumulator.
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Add one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sample mean (0 if empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Unbiased sample variance (0 if fewer than two observations).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Standard error of the mean — the paper's error bars ("standard mean
    /// error range based on five simulation instances").
    pub fn sem(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.stddev() / (self.n as f64).sqrt()
        }
    }

    /// Minimum observation (NaN if empty).
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.min
        }
    }

    /// Maximum observation (NaN if empty).
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.max
        }
    }

    /// Merge another accumulator into this one (parallel Welford).
    pub fn merge(&mut self, other: &Online) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let d = other.mean - self.mean;
        let n = n1 + n2;
        self.mean += d * n2 / n;
        self.m2 += other.m2 + d * d * n1 * n2 / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Batch summary of a sample: percentiles plus moments.
#[derive(Clone, Debug)]
pub struct Summary {
    /// Number of samples.
    pub n: usize,
    /// Sample mean.
    pub mean: f64,
    /// Sample standard deviation.
    pub stddev: f64,
    /// Standard error of the mean.
    pub sem: f64,
    /// Minimum.
    pub min: f64,
    /// Median (p50).
    pub median: f64,
    /// 95th percentile.
    pub p95: f64,
    /// Maximum.
    pub max: f64,
}

impl Summary {
    /// Summarize a sample (sorts a copy; `xs` may be in any order).
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty sample");
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut acc = Online::new();
        for &x in xs {
            acc.push(x);
        }
        Summary {
            n: xs.len(),
            mean: acc.mean(),
            stddev: acc.stddev(),
            sem: acc.sem(),
            min: sorted[0],
            median: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            max: sorted[sorted.len() - 1],
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut acc = Online::new();
        for &x in &xs {
            acc.push(x);
        }
        assert!((acc.mean() - 5.0).abs() < 1e-12);
        // Sample variance of this classic set is 32/7.
        assert!((acc.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(acc.min(), 2.0);
        assert_eq!(acc.max(), 9.0);
    }

    #[test]
    fn merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin()).collect();
        let mut whole = Online::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut a = Online::new();
        let mut b = Online::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
        assert!((a.variance() - whole.variance()).abs() < 1e-10);
        assert_eq!(a.count(), whole.count());
    }

    #[test]
    fn percentiles_interpolate() {
        let sorted = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile_sorted(&sorted, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile_sorted(&sorted, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile_sorted(&sorted, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_fields_consistent() {
        let xs: Vec<f64> = (1..=101).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.n, 101);
        assert!((s.mean - 51.0).abs() < 1e-12);
        assert!((s.median - 51.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 101.0);
        assert!(s.sem > 0.0);
    }

    #[test]
    fn sem_shrinks_with_n() {
        let mut small = Online::new();
        let mut large = Online::new();
        let mut rng = crate::sim::rng::Rng::new(11);
        for i in 0..10 {
            small.push(rng.unit_f64());
            let _ = i;
        }
        for _ in 0..1000 {
            large.push(rng.unit_f64());
        }
        assert!(large.sem() < small.sem());
    }
}
