//! A TOML-subset parser for the config system.
//!
//! Supported (everything the config files use):
//! * top-level and nested tables: `[section]`, `[a.b]`
//! * key/value pairs: strings (`"..."` with escapes), integers, floats,
//!   booleans, and homogeneous arrays of those scalars
//! * comments (`# ...`), blank lines, and `key = value` whitespace freedom
//!
//! Not supported (rejected with an error rather than misparsed): inline
//! tables, multi-line strings, dates, array-of-tables.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// UTF-8 string.
    Str(String),
    /// 64-bit signed integer.
    Int(i64),
    /// 64-bit float.
    Float(f64),
    /// Boolean.
    Bool(bool),
    /// Homogeneous array of scalars.
    Array(Vec<Value>),
    /// Nested table.
    Table(BTreeMap<String, Value>),
}

impl Value {
    /// As string, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    /// As integer (accepting exact floats too).
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            Value::Float(f) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }
    /// As float (accepting integers).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    /// As boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// As array slice.
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
    /// As table.
    pub fn as_table(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Table(t) => Some(t),
            _ => None,
        }
    }
    /// Dotted-path lookup, e.g. `get("model.axelrod.features")`.
    pub fn get(&self, path: &str) -> Option<&Value> {
        let mut cur = self;
        for seg in path.split('.') {
            cur = cur.as_table()?.get(seg)?;
        }
        Some(cur)
    }
}

impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::Int(v)
    }
}

impl From<i32> for Value {
    fn from(v: i32) -> Self {
        Value::Int(v as i64)
    }
}

impl From<usize> for Value {
    fn from(v: usize) -> Self {
        Value::Int(v as i64)
    }
}

impl From<u64> for Value {
    fn from(v: u64) -> Self {
        Value::Int(v as i64)
    }
}

impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Float(v)
    }
}

impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}

impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Str(v.to_string())
    }
}

impl From<String> for Value {
    fn from(v: String) -> Self {
        Value::Str(v)
    }
}

/// Parse error with 1-based line information.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    /// 1-based line number.
    pub line: usize,
    /// Human-readable message.
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "TOML parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

fn err(line: usize, msg: impl Into<String>) -> ParseError {
    ParseError {
        line,
        msg: msg.into(),
    }
}

/// Parse a TOML-subset document into a root table.
pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut root: BTreeMap<String, Value> = BTreeMap::new();
    let mut current_path: Vec<String> = Vec::new();

    for (idx, raw) in text.lines().enumerate() {
        let lineno = idx + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let inner = rest
                .strip_suffix(']')
                .ok_or_else(|| err(lineno, "unterminated table header"))?;
            if inner.starts_with('[') {
                return Err(err(lineno, "array-of-tables is not supported"));
            }
            let path: Vec<String> = inner.split('.').map(|s| s.trim().to_string()).collect();
            if path.iter().any(|s| s.is_empty()) {
                return Err(err(lineno, "empty table-path segment"));
            }
            ensure_table(&mut root, &path, lineno)?;
            current_path = path;
        } else {
            let eq = line
                .find('=')
                .ok_or_else(|| err(lineno, "expected `key = value`"))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(err(lineno, "empty key"));
            }
            let vtext = line[eq + 1..].trim();
            let value = parse_value(vtext, lineno)?;
            let table = navigate(&mut root, &current_path, lineno)?;
            if table.insert(key.to_string(), value).is_some() {
                return Err(err(lineno, format!("duplicate key `{key}`")));
            }
        }
    }
    Ok(Value::Table(root))
}

fn strip_comment(line: &str) -> &str {
    // A `#` inside a quoted string does not start a comment.
    let mut in_str = false;
    let mut prev_backslash = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' if !prev_backslash => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
        prev_backslash = c == '\\' && !prev_backslash;
    }
    line
}

fn ensure_table(
    root: &mut BTreeMap<String, Value>,
    path: &[String],
    lineno: usize,
) -> Result<(), ParseError> {
    let mut cur = root;
    for seg in path {
        let entry = cur
            .entry(seg.clone())
            .or_insert_with(|| Value::Table(BTreeMap::new()));
        cur = match entry {
            Value::Table(t) => t,
            _ => return Err(err(lineno, format!("`{seg}` is not a table"))),
        };
    }
    Ok(())
}

fn navigate<'a>(
    root: &'a mut BTreeMap<String, Value>,
    path: &[String],
    lineno: usize,
) -> Result<&'a mut BTreeMap<String, Value>, ParseError> {
    let mut cur = root;
    for seg in path {
        let entry = cur
            .get_mut(seg)
            .ok_or_else(|| err(lineno, format!("missing table `{seg}`")))?;
        cur = match entry {
            Value::Table(t) => t,
            _ => return Err(err(lineno, format!("`{seg}` is not a table"))),
        };
    }
    Ok(cur)
}

fn parse_value(text: &str, lineno: usize) -> Result<Value, ParseError> {
    let text = text.trim();
    if text.is_empty() {
        return Err(err(lineno, "missing value"));
    }
    if let Some(rest) = text.strip_prefix('"') {
        return parse_string(rest, lineno).map(Value::Str);
    }
    if let Some(inner) = text.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| err(lineno, "unterminated array (must be single-line)"))?;
        let mut items = Vec::new();
        for part in split_array_items(inner) {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            items.push(parse_value(part, lineno)?);
        }
        return Ok(Value::Array(items));
    }
    match text {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    let cleaned = text.replace('_', "");
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(err(lineno, format!("cannot parse value `{text}`")))
}

fn parse_string(rest: &str, lineno: usize) -> Result<String, ParseError> {
    let mut out = String::new();
    let mut chars = rest.chars();
    while let Some(c) = chars.next() {
        match c {
            '"' => {
                let trailing: String = chars.collect();
                if !trailing.trim().is_empty() {
                    return Err(err(lineno, "trailing characters after string"));
                }
                return Ok(out);
            }
            '\\' => match chars.next() {
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                Some('r') => out.push('\r'),
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                other => return Err(err(lineno, format!("bad escape `\\{other:?}`"))),
            },
            _ => out.push(c),
        }
    }
    Err(err(lineno, "unterminated string"))
}

/// Split array body on top-level commas (strings may contain commas).
fn split_array_items(inner: &str) -> Vec<String> {
    let mut items = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    let mut prev_backslash = false;
    for c in inner.chars() {
        match c {
            '"' if !prev_backslash => {
                in_str = !in_str;
                cur.push(c);
            }
            ',' if !in_str => items.push(std::mem::take(&mut cur)),
            _ => cur.push(c),
        }
        prev_backslash = c == '\\' && !prev_backslash;
    }
    if !cur.trim().is_empty() {
        items.push(cur);
    }
    items
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_sections() {
        let doc = r#"
# experiment config
name = "fig2"
steps = 2_000_000
omega = 0.95
paper_scale = false

[model.axelrod]
features = [25, 50, 100]
agents = 10000
"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("fig2"));
        assert_eq!(v.get("steps").unwrap().as_int(), Some(2_000_000));
        assert_eq!(v.get("omega").unwrap().as_float(), Some(0.95));
        assert_eq!(v.get("paper_scale").unwrap().as_bool(), Some(false));
        let feats = v.get("model.axelrod.features").unwrap().as_array().unwrap();
        assert_eq!(feats.len(), 3);
        assert_eq!(feats[1].as_int(), Some(50));
        assert_eq!(v.get("model.axelrod.agents").unwrap().as_int(), Some(10000));
    }

    #[test]
    fn string_escapes_and_comment_in_string() {
        let v = parse(r#"s = "a # not comment \"q\" \n" "#).unwrap();
        assert_eq!(v.get("s").unwrap().as_str(), Some("a # not comment \"q\" \n"));
    }

    #[test]
    fn rejects_duplicate_keys() {
        assert!(parse("a = 1\na = 2").is_err());
    }

    #[test]
    fn rejects_garbage_values() {
        assert!(parse("a = nope").is_err());
        assert!(parse("a = [1, 2").is_err());
        assert!(parse("[unclosed").is_err());
    }

    #[test]
    fn array_of_strings_with_commas() {
        let v = parse(r#"xs = ["a,b", "c"]"#).unwrap();
        let xs = v.get("xs").unwrap().as_array().unwrap();
        assert_eq!(xs[0].as_str(), Some("a,b"));
        assert_eq!(xs[1].as_str(), Some("c"));
    }

    #[test]
    fn nested_tables_merge() {
        let doc = "[a]\nx = 1\n[a.b]\ny = 2\n";
        let v = parse(doc).unwrap();
        assert_eq!(v.get("a.x").unwrap().as_int(), Some(1));
        assert_eq!(v.get("a.b.y").unwrap().as_int(), Some(2));
    }

    #[test]
    fn error_carries_line_number() {
        let e = parse("ok = 1\nbad").unwrap_err();
        assert_eq!(e.line, 2);
    }
}
