//! A small, fast set of `u32` ids for worker records.
//!
//! Worker records absorb the ids of every incomplete task they pass during a
//! cycle and are queried once per task visit, so membership tests sit on the
//! protocol's hot path. Typical cardinality is tiny (a few × workers × C),
//! so the set starts as a linear-scan vector and spills to an
//! open-addressing table (splitmix-hashed, power-of-two capacity) only when
//! it grows. `clear` keeps capacity — records reset every cycle and must not
//! allocate at steady state.

const LINEAR_MAX: usize = 16;

/// Insert-and-query set of `u32` ids; no deletion (records only grow within
/// a cycle and are bulk-cleared at cycle start).
#[derive(Clone, Debug, Default)]
pub struct U32Set {
    /// Small mode storage (always the source of truth when `table` empty).
    small: Vec<u32>,
    /// Open-addressing table; `u32::MAX` marks empty slots.
    table: Vec<u32>,
    /// Number of elements in `table` mode.
    len: usize,
}

#[inline(always)]
fn hash(x: u32) -> u64 {
    // splitmix64 finalizer over the id.
    let mut z = (x as u64).wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl U32Set {
    /// Empty set.
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of stored ids.
    pub fn len(&self) -> usize {
        if self.table.is_empty() {
            self.small.len()
        } else {
            self.len
        }
    }

    /// Whether the set is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Membership test.
    #[inline]
    pub fn contains(&self, x: u32) -> bool {
        debug_assert_ne!(x, u32::MAX, "u32::MAX is reserved");
        if self.table.is_empty() {
            self.small.contains(&x)
        } else {
            let mask = self.table.len() - 1;
            let mut i = (hash(x) as usize) & mask;
            loop {
                let slot = self.table[i];
                if slot == x {
                    return true;
                }
                if slot == u32::MAX {
                    return false;
                }
                i = (i + 1) & mask;
            }
        }
    }

    /// Insert; returns true if newly added.
    #[inline]
    pub fn insert(&mut self, x: u32) -> bool {
        debug_assert_ne!(x, u32::MAX, "u32::MAX is reserved");
        if self.table.is_empty() {
            if self.small.contains(&x) {
                return false;
            }
            if self.small.len() < LINEAR_MAX {
                self.small.push(x);
                return true;
            }
            self.spill();
        }
        self.insert_table(x)
    }

    fn spill(&mut self) {
        let cap = (LINEAR_MAX * 4).next_power_of_two();
        self.table = vec![u32::MAX; cap];
        self.len = 0;
        let small = std::mem::take(&mut self.small);
        for x in small {
            self.insert_table(x);
        }
    }

    fn insert_table(&mut self, x: u32) -> bool {
        if (self.len + 1) * 4 > self.table.len() * 3 {
            self.grow();
        }
        let mask = self.table.len() - 1;
        let mut i = (hash(x) as usize) & mask;
        loop {
            let slot = self.table[i];
            if slot == x {
                return false;
            }
            if slot == u32::MAX {
                self.table[i] = x;
                self.len += 1;
                return true;
            }
            i = (i + 1) & mask;
        }
    }

    fn grow(&mut self) {
        let new_cap = self.table.len() * 2;
        let old = std::mem::replace(&mut self.table, vec![u32::MAX; new_cap]);
        self.len = 0;
        for x in old {
            if x != u32::MAX {
                self.insert_table(x);
            }
        }
    }

    /// Remove all elements, keeping allocated capacity (no allocation).
    pub fn clear(&mut self) {
        self.small.clear();
        if !self.table.is_empty() {
            self.table.iter_mut().for_each(|s| *s = u32::MAX);
            self.len = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_contains_small() {
        let mut s = U32Set::new();
        assert!(s.insert(3));
        assert!(!s.insert(3));
        assert!(s.contains(3));
        assert!(!s.contains(4));
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn spills_to_table_and_stays_correct() {
        let mut s = U32Set::new();
        for i in 0..1000u32 {
            assert!(s.insert(i * 7));
        }
        assert_eq!(s.len(), 1000);
        for i in 0..1000u32 {
            assert!(s.contains(i * 7));
            assert!(!s.contains(i * 7 + 1));
        }
    }

    #[test]
    fn clear_retains_capacity_and_empties() {
        let mut s = U32Set::new();
        for i in 0..100 {
            s.insert(i);
        }
        let cap = s.table.len();
        s.clear();
        assert!(s.is_empty());
        assert!(!s.contains(5));
        assert_eq!(s.table.len(), cap);
        s.insert(7);
        assert!(s.contains(7));
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn matches_std_hashset_reference() {
        use std::collections::HashSet;
        let mut ours = U32Set::new();
        let mut theirs = HashSet::new();
        let mut rng = crate::sim::rng::Rng::new(99);
        for _ in 0..5000 {
            let x = rng.next_u32() % 512;
            assert_eq!(ours.insert(x), theirs.insert(x));
        }
        for x in 0..512 {
            assert_eq!(ours.contains(x), theirs.contains(&x));
        }
    }
}
