//! Cost-model calibration: measure the native cost of each protocol
//! micro-action and of model task execution on *this* machine, so the
//! virtual testbed's time axis reflects real hardware.
//!
//! Calibration strategy (see EXPERIMENTS.md §Calibration for a run log):
//!
//! * **Protocol primitives** are micro-benchmarked directly against the
//!   real implementation: visitor-slot acquire/release pairs, chain
//!   append/unlink, record probe/absorb, per-task RNG stream setup.
//! * **Model execution** is measured by running the *sequential* engine
//!   over a sample of tasks and dividing by the total `task_work`,
//!   yielding ns per work unit for that model and parameter set.
//!
//! All measurements use monotonic `Instant` timing around tight loops with
//! `black_box` to defeat the optimizer.

use std::hint::black_box;
use std::time::Instant;

use crate::chain::Chain;
use crate::model::{Model, TaskSource as _};
use crate::sim::rng::TaskRng;
use crate::util::u32set::U32Set;

use super::cost::CostModel;

fn time_per_iter(iters: u64, mut f: impl FnMut()) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_nanos() as f64 / iters as f64
}

/// Measure protocol micro-action costs on this machine. Takes ~1 s.
pub fn calibrate() -> CostModel {
    const N: u64 = 200_000;

    // Visitor slot: uncontended acquire+release pair.
    let occ = crate::chain::node::Occupancy::default();
    let slot_pair = time_per_iter(N, || {
        occ.acquire();
        occ.release();
    });

    // Record probe + absorb on a typical small record.
    let mut set = U32Set::new();
    let mut i = 0u32;
    let set_probe = time_per_iter(N, || {
        black_box(set.contains(black_box(i % 64)));
        i = i.wrapping_add(1);
    });
    let mut j = 0u32;
    let set_absorb = time_per_iter(N, || {
        set.insert(black_box(j % 64));
        j = j.wrapping_add(1);
        if j % 64 == 0 {
            set.clear();
        }
    });

    // Chain structural ops: append then unlink, amortized per task. The
    // chain stays at one live task, so after the first iteration every
    // append recycles a slot — exactly the steady-state path.
    let chain: Chain<u32> = Chain::new();
    let structural = time_per_iter(N / 4, || {
        let last = chain.head(); // the chain is empty between iterations
        chain.acquire(last);
        chain.acquire(chain.tail());
        let node = chain.append_after(last, 7);
        chain.release(chain.tail());
        chain.release(last);
        chain.acquire(node);
        chain.begin_execution(node);
        chain.unlink(node);
        chain.release(node);
    });
    // Roughly: an append (alloc + 3 link locks) costs ~60% of the pair, an
    // unlink (erase lock + 3 link locks, no alloc) ~40%.
    let create = structural * 0.6;
    let erase = structural * 0.4;

    // Per-task RNG stream setup (the fixed execution cost).
    let mut k = 0u64;
    let rng_setup = time_per_iter(N, || {
        let mut r = TaskRng::for_task(black_box(1), black_box(k));
        black_box(r.next_u64());
        k = k.wrapping_add(1);
    });

    CostModel {
        enter_ns: slot_pair,
        visit_ns: slot_pair + set_probe,
        absorb_ns: set_absorb,
        create_ns: create,
        erase_ns: erase,
        cycle_end_ns: slot_pair * 0.5,
        retry_ns: slot_pair,
        exec_fixed_ns: rng_setup,
        exec_unit_ns: CostModel::default().exec_unit_ns, // model-specific; see below
        idle_ns: slot_pair * 2.0,
    }
}

/// Measure ns per `task_work` unit for a concrete model by executing a
/// sample of its tasks sequentially. The model's state advances — pass a
/// throwaway instance. Returns `(exec_unit_ns, sampled_tasks)`.
pub fn calibrate_exec<M: Model>(model: &M, max_tasks: u64, cost: &CostModel) -> (f64, u64) {
    let seed = 0xCA11B;
    let mut source = model.source(seed);
    let mut recipes = Vec::new();
    let mut total_work = 0.0f64;
    while let Some(r) = source.next_task() {
        total_work += model.task_work(&r);
        recipes.push(r);
        if recipes.len() as u64 >= max_tasks {
            break;
        }
    }
    assert!(!recipes.is_empty(), "model produced no tasks");
    let t0 = Instant::now();
    for (seq, r) in recipes.iter().enumerate() {
        let mut rng = TaskRng::for_task(seed, seq as u64);
        model.execute(black_box(r), &mut rng);
    }
    let total_ns = t0.elapsed().as_nanos() as f64;
    let n = recipes.len() as u64;
    // Subtract the fixed per-task cost, attribute the rest to work units.
    let unit = ((total_ns - cost.exec_fixed_ns * n as f64) / total_work).max(0.01);
    (unit, n)
}

/// Convenience: fully calibrated cost model for a concrete model instance.
pub fn calibrated_for<M: Model>(model: &M, sample_tasks: u64) -> CostModel {
    let mut cost = calibrate();
    let (unit, _) = calibrate_exec(model, sample_tasks, &cost);
    cost.exec_unit_ns = unit;
    cost
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testkit::IncModel;

    #[test]
    fn calibration_produces_sane_costs() {
        let c = calibrate();
        c.validate().unwrap();
        // On any real machine these land well inside (0.5 ns, 100 µs).
        for v in [c.enter_ns, c.visit_ns, c.create_ns, c.erase_ns] {
            assert!(v > 0.5 && v < 1e5, "cost {v} out of range");
        }
    }

    #[test]
    fn exec_calibration_scales_with_work() {
        let cost = CostModel::default();
        let light = IncModel::with_work(2000, 64, 0);
        let heavy = IncModel::with_work(2000, 64, 5000);
        let (u_light, n1) = calibrate_exec(&light, 2000, &cost);
        let (u_heavy, n2) = calibrate_exec(&heavy, 2000, &cost);
        assert_eq!(n1, 2000);
        assert_eq!(n2, 2000);
        // ns/unit should be in the same ballpark for both (work-normalized);
        // mostly this asserts both are positive and finite.
        assert!(u_light > 0.0 && u_light.is_finite());
        assert!(u_heavy > 0.0 && u_heavy.is_finite());
        // The heavy model's *per-task* time must dominate the light one's.
        let per_task_light = u_light * 1.0;
        let per_task_heavy = u_heavy * 5001.0;
        assert!(per_task_heavy > per_task_light * 10.0);
    }

    #[test]
    fn calibrated_for_returns_valid_model() {
        let m = IncModel::with_work(500, 16, 100);
        let c = calibrated_for(&m, 500);
        c.validate().unwrap();
        assert!(c.exec_unit_ns > 0.0);
    }
}
