//! The virtual testbed's cost model: nanoseconds per protocol micro-action.
//!
//! Default values were measured on this repository's host (single-core
//! Xeon @ 2.1 GHz, release build) via [`super::calibrate`]; rerun
//! `adapar calibrate` to refresh them for another machine. The *ratios*
//! between protocol costs and per-unit execution cost are what shape the
//! figures; absolute values only scale the time axis.

/// Nanosecond costs of protocol micro-actions and model execution.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostModel {
    /// Entering the chain at a cycle start (head slot + record reset).
    pub enter_ns: f64,
    /// Arriving at a node: slot acquisition, pointer read, state check and
    /// the record's dependence test.
    pub visit_ns: f64,
    /// Absorbing a passed task's recipe into the record.
    pub absorb_ns: f64,
    /// Creating a task: source poll, node allocation, splice.
    pub create_ns: f64,
    /// Erasing a task: unlink under the erase lock plus counters.
    pub erase_ns: f64,
    /// Returning to the start of the chain at a cycle end.
    pub cycle_end_ns: f64,
    /// A wasted arrival at an erased node (retry from previous node).
    pub retry_ns: f64,
    /// Fixed per-execution cost (claiming the task, RNG stream setup).
    pub exec_fixed_ns: f64,
    /// Execution cost per `Model::task_work` unit.
    pub exec_unit_ns: f64,
    /// Idle backoff applied to a cycle that neither executed nor created
    /// (models `yield_now`; prevents zero-cost spinning in virtual time).
    pub idle_ns: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        // Measured via `adapar calibrate` on the reference host after the
        // §Perf optimization pass (atomic-fast-path visitor slots,
        // pre-linked node construction); see EXPERIMENTS.md §Calibration.
        Self {
            enter_ns: 18.5,
            visit_ns: 21.0,
            absorb_ns: 4.7,
            create_ns: 247.0,
            erase_ns: 165.0,
            cycle_end_ns: 9.3,
            retry_ns: 18.5,
            exec_fixed_ns: 4.3,
            exec_unit_ns: 1.6,
            idle_ns: 37.0,
        }
    }
}

impl CostModel {
    /// Execution duration for a task of the given work (see
    /// `Model::task_work`).
    #[inline]
    pub fn exec_ns(&self, work: f64) -> f64 {
        self.exec_fixed_ns + self.exec_unit_ns * work
    }

    /// A cost model with all protocol overhead zeroed (ideal machine):
    /// used by tests to check the DES against hand-computable schedules
    /// and by the ablation that isolates overhead effects.
    pub fn ideal(exec_unit_ns: f64) -> Self {
        Self {
            enter_ns: 0.0,
            visit_ns: 0.0,
            absorb_ns: 0.0,
            create_ns: 0.0,
            erase_ns: 0.0,
            cycle_end_ns: 0.0,
            retry_ns: 0.0,
            exec_fixed_ns: 0.0,
            exec_unit_ns,
            idle_ns: 1.0, // must stay positive: zero-cost spins would hang virtual time
        }
    }

    /// Sanity check: all costs non-negative, idle positive.
    pub fn validate(&self) -> Result<(), String> {
        let fields = [
            ("enter_ns", self.enter_ns),
            ("visit_ns", self.visit_ns),
            ("absorb_ns", self.absorb_ns),
            ("create_ns", self.create_ns),
            ("erase_ns", self.erase_ns),
            ("cycle_end_ns", self.cycle_end_ns),
            ("retry_ns", self.retry_ns),
            ("exec_fixed_ns", self.exec_fixed_ns),
            ("exec_unit_ns", self.exec_unit_ns),
        ];
        for (name, v) in fields {
            if !v.is_finite() || v < 0.0 {
                return Err(format!("cost {name} = {v} is invalid"));
            }
        }
        if !(self.idle_ns > 0.0) {
            return Err("idle_ns must be strictly positive".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        CostModel::default().validate().unwrap();
    }

    #[test]
    fn exec_cost_is_affine_in_work() {
        let c = CostModel::default();
        let base = c.exec_ns(0.0);
        assert!((c.exec_ns(100.0) - base - 100.0 * c.exec_unit_ns).abs() < 1e-9);
    }

    #[test]
    fn ideal_rejects_only_nonpositive_idle() {
        CostModel::ideal(1.0).validate().unwrap();
        let mut bad = CostModel::ideal(1.0);
        bad.idle_ns = 0.0;
        assert!(bad.validate().is_err());
        let mut neg = CostModel::default();
        neg.visit_ns = -1.0;
        assert!(neg.validate().is_err());
    }

    #[test]
    fn zero_work_tasks_still_pay_the_fixed_cost() {
        // Zero-cost blocks (chaos plans set mul = 0.0) must not produce
        // zero-duration executions: the DES relies on exec_fixed_ns to
        // keep virtual time advancing.
        let c = CostModel::default();
        assert_eq!(c.exec_ns(0.0), c.exec_fixed_ns);
        assert!(c.exec_ns(0.0) > 0.0);
        let ideal = CostModel::ideal(2.0);
        assert_eq!(ideal.exec_ns(0.0), 0.0, "ideal machine may be free");
    }

    #[test]
    fn extreme_skew_stays_finite_and_monotone() {
        // A 0x/1e6x chaos skew spans 9+ orders of magnitude; the affine
        // map must stay finite and strictly ordered across all of it.
        let c = CostModel::default();
        let works = [0.0, 1.0, 1e3, 1e6, 1e9];
        let costs: Vec<f64> = works.iter().map(|&w| c.exec_ns(w)).collect();
        for w in costs.windows(2) {
            assert!(w[0] < w[1], "exec_ns must grow with work: {costs:?}");
            assert!(w[1].is_finite());
        }
    }

    #[test]
    fn validate_rejects_nan_and_infinite_costs() {
        let mut nan = CostModel::default();
        nan.exec_unit_ns = f64::NAN;
        assert!(nan.validate().is_err());
        let mut inf = CostModel::default();
        inf.create_ns = f64::INFINITY;
        assert!(inf.validate().is_err());
    }

    #[test]
    fn scaled_models_keep_cost_ratios() {
        // Chaos exec-scale injection multiplies exec_unit_ns; the shape
        // of the figures depends only on ratios, so scaling must commute
        // with exec_ns up to the fixed part.
        let base = CostModel::default();
        let mut scaled = base;
        scaled.exec_unit_ns *= 16.0;
        scaled.validate().unwrap();
        let w = 37.0;
        let expected = base.exec_fixed_ns + 16.0 * base.exec_unit_ns * w;
        assert!((scaled.exec_ns(w) - expected).abs() < 1e-9);
    }
}
