//! Virtual-core testbed: a deterministic discrete-event simulation (DES)
//! of the worker–chain protocol with `n` *virtual* cores.
//!
//! The host machine has a single physical core, so the paper's multi-core
//! wall-clock figures (Fig. 2, Fig. 3: T vs task size for n ∈ {1..5})
//! cannot be measured directly. Instead of skipping the experiment, the
//! testbed replays the *exact* protocol semantics — visitor slots,
//! waiting-behind, passing executing tasks, the erase lock, per-cycle
//! creation caps — in virtual time, with every micro-action costed by a
//! [`cost::CostModel`] **calibrated from native single-core
//! measurements** ([`calibrate`]).
//!
//! The protocol's speedup behaviour is a function of (i) the dependence
//! structure of the task chain and (ii) the ratio of task-execution cost
//! to protocol overhead; both are preserved exactly (the DES executes the
//! same records, the same task streams — it even executes the *model
//! itself*, so its final state is bit-identical to the sequential engine,
//! which the test suite asserts). What is *not* modelled is memory-bus
//! contention between cores, a second-order effect at n ≤ 5 (DESIGN.md §2).

pub mod calibrate;
pub mod cost;
pub mod vengine;

pub use calibrate::{calibrate, calibrate_exec, calibrated_for};
pub use cost::CostModel;
pub use vengine::VirtualEngine;
