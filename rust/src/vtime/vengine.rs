//! The discrete-event simulation of the protocol (virtual cores).
//!
//! Single-threaded, deterministic: workers are state machines advanced one
//! micro-action at a time, ordered by a `(virtual time, worker id)`
//! priority queue. Slot waits are event-driven (a freed slot hands off to
//! the first queued waiter), never polled. The DES executes the actual
//! model (same records, same RNG streams), so besides virtual timings it
//! produces the exact simulation state — asserted bit-identical to the
//! sequential engine by the test suite.

use std::cmp::Ordering as CmpOrdering;
use std::collections::{BinaryHeap, VecDeque};

use crate::api::observe::{EpochGate, ObsProbe, Observer};
use crate::chaos::FaultHook;
use crate::model::{Model, Record, TaskSource};
use crate::protocol::{ProtocolStats, RunReport, TimeBasis, WorkerStats};
use crate::sim::rng::TaskRng;
use crate::trace::{TraceCore, TraceHandle, TraceMode, NONE_ID, NONE_SHARD};

use super::cost::CostModel;

/// Virtual-core engine configuration + entry point.
#[derive(Clone, Copy, Debug)]
pub struct VirtualEngine {
    /// `n` — number of virtual workers/cores.
    pub workers: usize,
    /// `C` — max creations per worker cycle.
    pub tasks_per_cycle: u32,
    /// Simulation seed.
    pub seed: u64,
    /// Micro-action costs.
    pub cost: CostModel,
    /// Causal-tracing mode (inert). Virtual traces carry *virtual*
    /// timestamps (the DES clocks), so `trace-analyze` attributes the
    /// modelled schedule rather than host wall time.
    pub trace: TraceMode,
    /// `W` — streaming materialization window (ISSUE 10, DESIGN.md §14):
    /// at most this many tasks live at any virtual instant; `0` disables
    /// streaming. Inert for simulation state and observation traces (a
    /// stalled virtual worker idles exactly like one that found the
    /// epoch budget spent); only node residency — and, through the idle
    /// cycles, the *virtual* clocks — changes.
    pub window: u64,
}

// ---------------------------------------------------------------------------
// internal DES structures
// ---------------------------------------------------------------------------

const HEAD: usize = 0;
const TAIL: usize = 1;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum VState {
    Pending,
    Executing,
    Erased,
}

struct VNode<R> {
    seq: u64,
    recipe: Option<R>,
    state: VState,
    /// Worker currently located here (holding the visitor slot).
    occupant: Option<usize>,
    waiters: VecDeque<usize>,
    prev: usize,
    next: usize,
}

/// What a worker will do when it next runs.
#[derive(Clone, Copy, Debug)]
enum Phase {
    /// Begin a new cycle (reset record, try to enter at head).
    StartCycle,
    /// Holding `from`'s slot; step to its successor.
    WantNext { from: usize },
    /// Slot of `node` was just granted while holding `from`: complete the
    /// arrival (pay visit cost, release `from`, process `node`).
    ArriveGranted { from: usize, node: usize },
    /// Holding `from` and the tail slot was just granted: create.
    CreateGranted { from: usize },
    /// Execution of `node` finished at the current clock; need the node's
    /// slot back to erase it.
    WantEraseSlot { node: usize },
    /// Slot of executed `node` re-acquired: erase it.
    EraseGranted { node: usize },
    /// Head slot granted at cycle start.
    EnterGranted,
    /// Finished.
    Done,
}

struct VWorker<Rec> {
    clock: f64,
    phase: Phase,
    record: Rec,
    created_this_cycle: u32,
    /// Work performed in the current cycle (for idle detection).
    cycle_had_work: bool,
    stats: WorkerStats,
}

#[derive(PartialEq)]
struct Ev {
    time: f64,
    wid: usize,
}

impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Self) -> CmpOrdering {
        // min-heap by (time, wid): reverse for BinaryHeap.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.wid.cmp(&self.wid))
    }
}

struct Des<'m, M: Model> {
    model: &'m M,
    cost: CostModel,
    seed: u64,
    cap: u32,
    /// Per-worker trace lanes (empty when tracing is off); the DES is
    /// single-threaded, so each lane trivially has one producer.
    trace: Vec<TraceHandle<'m>>,
    nodes: Vec<VNode<M::Recipe>>,
    /// Erased node indices available for reuse (ISSUE 10): recycling
    /// keeps `nodes` at O(live) instead of one entry per task ever
    /// created. Safe because an erased node is unreachable — neighbors
    /// are relinked by the unlink and every waiter is redirected to the
    /// retry path before the index is freed.
    free: Vec<usize>,
    /// Free-list reuses (the report's `arena_recycled`).
    recycled: u64,
    workers: Vec<VWorker<M::Record>>,
    heap: BinaryHeap<Ev>,
    source: EpochGate<M::Source>,
    /// Streaming-window retirement handle (`None` when materialized).
    retire: Option<crate::model::RetireHandle>,
    exhausted: bool,
    live: usize,
    max_live: usize,
    created: u64,
    erased: u64,
    erase_free_at: f64,
}

impl VirtualEngine {
    /// Run the model on the virtual testbed. Returns the same unified
    /// [`RunReport`] as every other engine, with
    /// [`TimeBasis::Virtual`] marking `time_s` as deterministic virtual
    /// time (max over worker clocks).
    pub fn run<M: Model>(&self, model: &M) -> RunReport {
        self.run_epochs(model, None, None)
    }

    /// Run with epoch snapshots: at every `observer.every()` canonical
    /// tasks the DES's gated source reports (temporary) exhaustion, the
    /// event loop drains to quiescence, a frame is recorded, and the
    /// virtual workers resume at their current clocks — fully
    /// deterministic, like everything else in the testbed.
    pub fn run_observed<M: Model>(
        &self,
        model: &M,
        probe: ObsProbe<'_>,
        observer: &mut Observer,
    ) -> RunReport {
        self.run_epochs(model, Some((probe, observer)), None)
    }

    /// Run under fault injection (DESIGN.md §10): the hook is consulted
    /// once per epoch boundary — worker clocks are advanced by the
    /// epoch's stalls/jitter and the cost model is scaled by the mean
    /// skew before the epoch's events run. The DES event loop itself is
    /// untouched, so an injected run is exactly as deterministic as a
    /// clean one.
    pub fn run_chaos<M: Model>(&self, model: &M, hook: &mut FaultHook) -> RunReport {
        self.run_epochs(model, None, Some(hook))
    }

    /// [`run_chaos`](Self::run_chaos) with epoch snapshots; the
    /// observer's cadence wins over the plan's `every` override (trace
    /// identity is defined at observation boundaries).
    pub fn run_chaos_observed<M: Model>(
        &self,
        model: &M,
        probe: ObsProbe<'_>,
        observer: &mut Observer,
        hook: &mut FaultHook,
    ) -> RunReport {
        self.run_epochs(model, Some((probe, observer)), Some(hook))
    }

    fn run_epochs<M: Model>(
        &self,
        model: &M,
        mut obs: Option<(ObsProbe<'_>, &mut Observer)>,
        mut hook: Option<&mut FaultHook>,
    ) -> RunReport {
        assert!(self.workers >= 1 && self.tasks_per_cycle >= 1);
        self.cost.validate().expect("invalid cost model");
        let every = match &obs {
            Some((_, o)) => o.gate_cadence(),
            None => match &hook {
                Some(h) => h.every_or(u64::MAX),
                None => u64::MAX,
            },
        };

        let trc = TraceCore::start(self.trace, self.workers, "virtual", "virtual");
        let mut gate = EpochGate::new(model.source(self.seed));
        if self.window > 0 {
            gate.set_window(Some(crate::model::Window::new(self.window)));
        }
        let retire = gate.retire_handle();
        let mut des = Des {
            model,
            cost: self.cost,
            seed: self.seed,
            cap: self.tasks_per_cycle,
            trace: match &trc {
                Some(c) => (0..self.workers).map(|w| c.handle(w)).collect(),
                None => Vec::new(),
            },
            nodes: Vec::with_capacity(64),
            free: Vec::new(),
            recycled: 0,
            workers: Vec::with_capacity(self.workers),
            heap: BinaryHeap::new(),
            source: gate,
            retire,
            exhausted: false,
            live: 0,
            max_live: 0,
            created: 0,
            erased: 0,
            erase_free_at: 0.0,
        };
        // Sentinels.
        des.nodes.push(VNode {
            seq: u64::MAX,
            recipe: None,
            state: VState::Pending,
            occupant: None,
            waiters: VecDeque::new(),
            prev: HEAD,
            next: TAIL,
        });
        des.nodes.push(VNode {
            seq: u64::MAX,
            recipe: None,
            state: VState::Pending,
            occupant: None,
            waiters: VecDeque::new(),
            prev: HEAD,
            next: TAIL,
        });
        for w in 0..self.workers {
            des.workers.push(VWorker {
                clock: 0.0,
                phase: Phase::StartCycle,
                record: model.record(),
                created_this_cycle: 0,
                cycle_had_work: false,
                stats: WorkerStats {
                    worker: w,
                    ..Default::default()
                },
            });
            des.heap.push(Ev { time: 0.0, wid: w });
        }

        if let Some((probe, observer)) = obs.as_mut() {
            observer.record_initial(*probe);
        }
        loop {
            // Epoch-boundary injection: stalls and jitter advance worker
            // clocks (pending heap events keep earlier stamps, which the
            // dispatch assert permits); skew rescales execution costs
            // from the pristine base each epoch.
            if let Some(h) = hook.as_mut() {
                let faults = h.next_epoch(self.workers);
                for w in 0..self.workers {
                    des.workers[w].clock += faults.delay_ns(w);
                }
                des.cost = faults.scaled_cost(&self.cost);
            }
            des.source.open(every);
            des.run_to_completion();
            // Quiescent: every created task executed, all workers parked.
            if let Some((probe, observer)) = obs.as_mut() {
                observer.record(des.source.emitted(), probe());
            }
            if let Some(c) = &trc {
                // The epoch's quiescent point in virtual time is the
                // latest worker clock.
                let t = des.workers.iter().fold(0.0f64, |a, w| a.max(w.clock));
                c.coordinator().epoch_mark_at(des.source.emitted(), t as u64);
            }
            if des.source.finished() {
                break;
            }
            // Resume the next epoch: clear the per-epoch exhaustion and
            // re-arm every worker at its current virtual clock.
            des.exhausted = false;
            for w in 0..self.workers {
                des.workers[w].phase = Phase::StartCycle;
                des.push(w);
            }
        }

        let mut totals = WorkerStats::default();
        let mut per_worker = Vec::with_capacity(self.workers);
        let mut t_end: f64 = 0.0;
        for w in &des.workers {
            totals.merge(&w.stats);
            per_worker.push(w.stats.clone());
            t_end = t_end.max(w.clock);
        }
        let chain = ProtocolStats {
            tasks_created: des.created,
            tasks_executed: des.erased,
            max_chain_len: des.max_live,
            batch: 1,
            // The node pool is the DES's arena: recycling keeps its length
            // at O(peak live), and a drained run holds only the sentinels.
            arena_capacity: des.nodes.len(),
            arena_high_water: des.max_live + 2,
            arena_recycled: des.recycled,
            arena_live: 2,
            state_bytes: crate::protocol::stats::state_bytes_total(
                model.state_bytes_per_task(),
                des.erased,
            ),
            ..Default::default()
        };
        // `des` holds `TraceHandle`s borrowing `trc`: end the borrow
        // before `finish` consumes the core.
        drop(des);
        RunReport {
            engine: "virtual",
            workers: self.workers,
            time_s: t_end * 1e-9,
            basis: TimeBasis::Virtual,
            totals,
            telemetry: Some(crate::protocol::stats::post_hoc_snapshot(
                &per_worker,
                &chain,
            )),
            per_worker,
            chain,
            sched: None,
            trace: trc.map(TraceCore::finish),
        }
    }
}

impl<'m, M: Model> Des<'m, M> {
    fn run_to_completion(&mut self) {
        while let Some(Ev { time, wid }) = self.heap.pop() {
            debug_assert!(time <= self.workers[wid].clock + 1e-6);
            self.dispatch(wid);
        }
        debug_assert!(self.exhausted && self.live == 0, "DES ended with work left");
    }

    fn push(&mut self, wid: usize) {
        self.heap.push(Ev {
            time: self.workers[wid].clock,
            wid,
        });
    }

    /// Try to take `node`'s slot for `wid`; on failure, queue as waiter
    /// (caller must have set the worker's wake phase beforehand).
    fn occupy_or_wait(&mut self, node: usize, wid: usize) -> bool {
        if self.nodes[node].occupant.is_none() {
            self.nodes[node].occupant = Some(wid);
            true
        } else {
            debug_assert_ne!(self.nodes[node].occupant, Some(wid));
            self.nodes[node].waiters.push_back(wid);
            false
        }
    }

    /// Release `node`'s slot at time `now`, handing off to the first
    /// waiter (whose pre-set phase describes its continuation).
    fn release(&mut self, node: usize, now: f64) {
        debug_assert!(self.nodes[node].occupant.is_some());
        self.nodes[node].occupant = None;
        if let Some(w) = self.nodes[node].waiters.pop_front() {
            self.nodes[node].occupant = Some(w);
            let wk = &mut self.workers[w];
            wk.clock = wk.clock.max(now);
            self.push(w);
        }
    }

    fn dispatch(&mut self, wid: usize) {
        let phase = self.workers[wid].phase;
        match phase {
            Phase::Done => {}
            Phase::StartCycle => {
                if self.exhausted && self.live == 0 {
                    self.workers[wid].phase = Phase::Done;
                    return;
                }
                {
                    let w = &mut self.workers[wid];
                    w.record.reset();
                    w.stats.cycles += 1;
                    w.created_this_cycle = 0;
                    w.cycle_had_work = false;
                    w.phase = Phase::EnterGranted;
                }
                if self.occupy_or_wait(HEAD, wid) {
                    self.dispatch_enter(wid);
                }
                // else: queued on head; wakes in EnterGranted.
            }
            Phase::EnterGranted => self.dispatch_enter(wid),
            Phase::WantNext { from } => self.dispatch_want_next(wid, from),
            Phase::ArriveGranted { from, node } => self.dispatch_arrive(wid, from, node),
            Phase::CreateGranted { from } => self.dispatch_create(wid, from),
            Phase::WantEraseSlot { node } => {
                self.workers[wid].phase = Phase::EraseGranted { node };
                if self.occupy_or_wait(node, wid) {
                    self.dispatch_erase(wid, node);
                }
            }
            Phase::EraseGranted { node } => self.dispatch_erase(wid, node),
        }
    }

    fn dispatch_enter(&mut self, wid: usize) {
        // Holding HEAD.
        self.workers[wid].clock += self.cost.enter_ns;
        self.workers[wid].phase = Phase::WantNext { from: HEAD };
        self.push(wid);
    }

    fn dispatch_want_next(&mut self, wid: usize, from: usize) {
        let next = self.nodes[from].next;
        if next == TAIL {
            // Creation path.
            if self.workers[wid].created_this_cycle >= self.cap || self.exhausted {
                self.end_cycle(wid, from);
                return;
            }
            self.workers[wid].phase = Phase::CreateGranted { from };
            if self.occupy_or_wait(TAIL, wid) {
                self.dispatch_create(wid, from);
            }
            return;
        }
        self.workers[wid].phase = Phase::ArriveGranted { from, node: next };
        if self.occupy_or_wait(next, wid) {
            self.dispatch_arrive(wid, from, next);
        }
    }

    fn dispatch_arrive(&mut self, wid: usize, from: usize, node: usize) {
        // Slot of `node` held; still holding `from`.
        if self.nodes[node].state == VState::Erased {
            // The executor erased it while we waited (unlink already moved
            // our wake to the retry path — this branch is for the rare
            // direct grant race kept for robustness).
            self.release(node, self.workers[wid].clock);
            self.workers[wid].clock += self.cost.retry_ns;
            self.workers[wid].stats.erased_retries += 1;
            self.workers[wid].phase = Phase::WantNext { from };
            self.push(wid);
            return;
        }
        self.workers[wid].clock += self.cost.visit_ns;
        let now = self.workers[wid].clock;
        self.release(from, now);
        self.process(wid, node);
    }

    /// Process an arrival at a live task node (slot held).
    fn process(&mut self, wid: usize, node: usize) {
        let state = self.nodes[node].state;
        match state {
            VState::Executing => {
                let recipe = self.nodes[node].recipe.clone().unwrap();
                let w = &mut self.workers[wid];
                w.record.absorb(&recipe);
                w.stats.passed_executing += 1;
                w.clock += self.cost.absorb_ns;
                w.phase = Phase::WantNext { from: node };
                self.push(wid);
            }
            VState::Pending => {
                let recipe = self.nodes[node].recipe.clone().unwrap();
                let depends = self.workers[wid].record.depends(&recipe);
                if depends {
                    let w = &mut self.workers[wid];
                    w.record.absorb(&recipe);
                    w.stats.skipped_dependent += 1;
                    w.clock += self.cost.absorb_ns;
                    w.phase = Phase::WantNext { from: node };
                    self.push(wid);
                } else {
                    // Execute: claim, free the slot (others may pass),
                    // burn virtual exec time, then reclaim to erase.
                    self.nodes[node].state = VState::Executing;
                    let seq = self.nodes[node].seq;
                    let now = self.workers[wid].clock;
                    self.release(node, now);
                    // Execute the model *now*: any order the DES picks is
                    // conflict-free (records), so state equals sequential.
                    let mut rng = TaskRng::for_task(self.seed, seq);
                    self.model.execute(&recipe, &mut rng);
                    let work = self.model.task_work(&recipe);
                    let th = self.trace.get(wid).copied();
                    let w = &mut self.workers[wid];
                    w.clock += self.cost.exec_ns(work);
                    w.cycle_had_work = true;
                    w.phase = Phase::WantEraseSlot { node };
                    if let Some(th) = th {
                        // Span in virtual time: the modelled execution
                        // occupies [claim clock, claim clock + exec cost).
                        th.exec(seq, NONE_ID, NONE_SHARD, now as u64, w.clock as u64);
                    }
                    self.push(wid);
                }
            }
            VState::Erased => unreachable!("erased nodes are retried at arrival"),
        }
    }

    fn dispatch_create(&mut self, wid: usize, from: usize) {
        // Holding `from` and TAIL.
        if self.exhausted {
            // Someone exhausted the source while we waited for the slot.
            let now = self.workers[wid].clock;
            self.release(TAIL, now);
            self.end_cycle(wid, from);
            return;
        }
        self.workers[wid].clock += self.cost.create_ns;
        match self.source.next_task() {
            None => {
                // A temporary streaming-window stall must NOT latch
                // exhaustion: the worker just ends its cycle and keeps
                // cycling — outstanding tasks retire at erase and reopen
                // room, so progress is guaranteed (live ≥ 1 while
                // stalled). Epoch boundaries happen only at true
                // budget/source exhaustion, keeping traces identical to
                // the materialized path.
                if !self.source.window_stalled() {
                    self.exhausted = true;
                }
                let now = self.workers[wid].clock;
                self.release(TAIL, now);
                self.end_cycle(wid, from);
            }
            Some(recipe) => {
                let seq = self.created;
                self.created += 1;
                self.live += 1;
                self.max_live = self.max_live.max(self.live);
                let node = VNode {
                    seq,
                    recipe: Some(recipe),
                    state: VState::Pending,
                    occupant: Some(wid), // step straight onto the new node
                    waiters: VecDeque::new(),
                    prev: from,
                    next: TAIL,
                };
                let prev = self.nodes[TAIL].prev;
                debug_assert_eq!(prev, from);
                // Reuse an erased slot when one is free — the node pool
                // stays O(live), not O(total tasks) (ISSUE 10).
                let idx = match self.free.pop() {
                    Some(i) => {
                        self.recycled += 1;
                        self.nodes[i] = node;
                        i
                    }
                    None => {
                        self.nodes.push(node);
                        self.nodes.len() - 1
                    }
                };
                self.nodes[from].next = idx;
                self.nodes[TAIL].prev = idx;
                let now = self.workers[wid].clock;
                self.release(TAIL, now);
                self.release(from, now);
                let w = &mut self.workers[wid];
                w.created_this_cycle += 1;
                w.stats.created += 1;
                w.cycle_had_work = true;
                self.process(wid, idx);
            }
        }
    }

    fn dispatch_erase(&mut self, wid: usize, node: usize) {
        // Slot of `node` re-acquired after execution: erase under the
        // (virtual) erase lock.
        let start = self.workers[wid].clock.max(self.erase_free_at);
        let end = start + self.cost.erase_ns;
        self.erase_free_at = end;
        self.workers[wid].clock = end;

        // Unlink.
        let (p, n) = (self.nodes[node].prev, self.nodes[node].next);
        self.nodes[p].next = n;
        self.nodes[n].prev = p;
        self.nodes[node].state = VState::Erased;
        self.nodes[node].recipe = None;
        self.live -= 1;
        self.erased += 1;

        // Wake every waiter on the erased node into the retry path: they
        // still hold their previous node, whose `next` now skips us.
        let waiters: Vec<usize> = self.nodes[node].waiters.drain(..).collect();
        self.nodes[node].occupant = None;
        for w in waiters {
            let (retry_from, ok) = match self.workers[w].phase {
                Phase::ArriveGranted { from, .. } => (from, true),
                _ => (0, false),
            };
            debug_assert!(ok, "waiter on task node must be an arriver");
            let wk = &mut self.workers[w];
            wk.clock = wk.clock.max(end) + self.cost.retry_ns;
            wk.stats.erased_retries += 1;
            wk.phase = Phase::WantNext { from: retry_from };
            self.push(w);
        }
        // Every observer is gone (waiters redirected above; arrivers hold
        // the slot, which blocked this erase): the index can be reused.
        self.free.push(node);
        // One canonical task done — reopen its streaming-window slot.
        if let Some(r) = &self.retire {
            r.retire(1);
        }

        self.workers[wid].stats.executed += 1;
        // Cycle ends after an execution.
        self.workers[wid].clock += self.cost.cycle_end_ns;
        self.workers[wid].phase = Phase::StartCycle;
        self.push(wid);
    }

    fn end_cycle(&mut self, wid: usize, held: usize) {
        let now = self.workers[wid].clock;
        self.release(held, now);
        let w = &mut self.workers[wid];
        w.clock += self.cost.cycle_end_ns;
        if !w.cycle_had_work {
            w.stats.idle_cycles += 1;
            w.clock += self.cost.idle_ns;
        }
        w.phase = Phase::StartCycle;
        self.push(wid);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testkit::IncModel;
    use crate::protocol::SequentialEngine;

    fn vengine(workers: usize, seed: u64) -> VirtualEngine {
        VirtualEngine {
            workers,
            tasks_per_cycle: 6,
            seed,
            cost: CostModel::default(),
            trace: crate::trace::TraceMode::Off,
            window: 0,
        }
    }

    #[test]
    fn virtual_state_matches_sequential_bitwise() {
        let seed = 3;
        let expected = {
            let m = IncModel::new(1500, 8);
            SequentialEngine::new(seed).run(&m);
            m.cells_snapshot()
        };
        for workers in [1, 2, 4, 5] {
            let m = IncModel::new(1500, 8);
            let rep = vengine(workers, seed).run(&m);
            assert_eq!(m.cells_snapshot(), expected, "n={workers}");
            assert_eq!(rep.chain.tasks_executed, 1500);
            assert_eq!(rep.totals.executed, 1500);
        }
    }

    #[test]
    fn virtual_run_is_deterministic() {
        let run = || {
            let m = IncModel::with_work(800, 16, 50);
            vengine(3, 9).run(&m).time_s
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn more_cores_speed_up_parallel_workload() {
        // 64 cells, heavy tasks: plenty of parallelism.
        let t = |workers| {
            let m = IncModel::with_work(2000, 64, 2000);
            vengine(workers, 1).run(&m).time_s
        };
        let t1 = t(1);
        let t2 = t(2);
        let t4 = t(4);
        assert!(t2 < t1 * 0.75, "2 cores: {t2:.6} vs {t1:.6}");
        assert!(t4 < t2 * 0.80, "4 cores: {t4:.6} vs {t2:.6}");
    }

    #[test]
    fn serial_workload_gains_at_most_pipelining() {
        // Single cell: fully dependent chain. Executions cannot overlap,
        // but workers may still pipeline task *creation* against the
        // running execution, so a small constant-factor gain (bounded by
        // create/(create+exec)) is legitimate — large speedups are not.
        let t = |workers| {
            let m = IncModel::with_work(500, 1, 500);
            vengine(workers, 2).run(&m).time_s
        };
        let t1 = t(1);
        let t4 = t(4);
        assert!(
            t4 >= t1 * 0.75,
            "serial chain must not truly parallelize: {t4:.6} vs {t1:.6}"
        );
        assert!(t4 <= t1 * 1.5, "extra workers must not wreck a serial chain");
    }

    #[test]
    fn ideal_cost_model_gives_near_linear_speedup() {
        // Zero protocol overhead + abundant parallelism => T(n) ≈ T(1)/n.
        let t = |workers| {
            let m = IncModel::with_work(4000, 4096, 100);
            VirtualEngine {
                workers,
                tasks_per_cycle: 6,
                seed: 4,
                cost: CostModel::ideal(1.0),
                trace: crate::trace::TraceMode::Off,
                window: 0,
            }
            .run(&m)
            .time_s
        };
        let t1 = t(1);
        let t4 = t(4);
        let speedup = t1 / t4;
        assert!(
            speedup > 3.3,
            "ideal machine should give near-linear speedup, got {speedup:.2}"
        );
    }

    #[test]
    fn injected_runs_preserve_sequential_state() {
        use crate::chaos::{plan, FaultHook};
        let seed = 5;
        let expected = {
            let m = IncModel::new(1200, 8);
            SequentialEngine::new(seed).run(&m);
            m.cells_snapshot()
        };
        for p in plan::bundled() {
            let m = IncModel::new(1200, 8);
            let mut hook = FaultHook::new(p.clone().with_every(200));
            let rep = vengine(3, seed).run_chaos(&m, &mut hook);
            assert_eq!(m.cells_snapshot(), expected, "plan `{}`", p.name);
            assert_eq!(rep.chain.tasks_executed, 1200, "plan `{}`", p.name);
            assert!(hook.epochs() >= 2, "plan `{}` must span epochs", p.name);
            assert!(hook.violations().is_empty(), "plan `{}`", p.name);
        }
    }

    #[test]
    fn injected_stalls_are_deterministic_and_cost_time() {
        use crate::chaos::{FaultHook, FaultPlan};
        let run = |ns: f64| {
            let m = IncModel::with_work(600, 16, 50);
            let mut hook =
                FaultHook::new(FaultPlan::new("s", 1).stall(0, 0, ns).with_every(100));
            vengine(2, 3).run_chaos(&m, &mut hook).time_s
        };
        assert_eq!(run(5_000.0), run(5_000.0));
        assert!(run(500_000.0) > run(0.0), "a long stall must show up in T");
    }

    #[test]
    fn streaming_window_bounds_node_pool_and_preserves_state() {
        let seed = 11;
        let expected = {
            let m = IncModel::new(1200, 8);
            SequentialEngine::new(seed).run(&m);
            m.cells_snapshot()
        };
        for window in [1u64, 7, 64] {
            let m = IncModel::new(1200, 8);
            let mut eng = vengine(4, seed);
            eng.window = window;
            let rep = eng.run(&m);
            assert_eq!(m.cells_snapshot(), expected, "W={window}");
            assert_eq!(rep.chain.tasks_executed, 1200, "W={window}");
            // live ≤ W at every instant, so pool ≤ W + sentinels.
            assert!(
                rep.chain.arena_high_water as u64 <= window + 2,
                "W={window}: high_water={}",
                rep.chain.arena_high_water
            );
            assert!(
                rep.chain.arena_capacity as u64 <= window + 2,
                "W={window}: capacity={}",
                rep.chain.arena_capacity
            );
            assert!(
                rep.chain.arena_recycled > 0,
                "W={window}: a bounded pool must recycle"
            );
        }
    }

    #[test]
    fn counters_consistent() {
        let m = IncModel::new(600, 4);
        let rep = vengine(3, 7).run(&m);
        assert_eq!(rep.totals.created, 600);
        assert_eq!(rep.totals.executed, 600);
        assert_eq!(rep.chain.tasks_created, 600);
        assert!(rep.chain.max_chain_len >= 1);
        assert!(rep.time_s > 0.0);
        assert_eq!(rep.per_worker.len(), 3);
    }
}
