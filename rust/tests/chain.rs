//! Arena-chain invariants (ISSUE 5): free-list reuse, generation-tag
//! staleness detection, leak-freedom after teardown — plus multi-worker
//! stress runs asserting that the creation batch size `B` is invisible
//! in final states *and* whole observation traces (the chain engines
//! must stay byte-identical to sequential at every batch size).

use std::sync::Arc;

use adapar::api::observe::Observer;
use adapar::chain::{Chain, Handle, NodeState};
use adapar::model::testkit::{env_batches, env_worker_counts, IncModel};
use adapar::protocol::{ParallelEngine, ProtocolConfig, SequentialEngine};

/// Worker-style append through the public slot API.
fn append<R>(chain: &Chain<R>, recipe: R) -> Handle {
    let mut last = chain.head();
    loop {
        let next = chain.next(last);
        if chain.is_tail(next) {
            break;
        }
        last = next;
    }
    chain.acquire(last);
    chain.acquire(chain.tail());
    let node = chain.append_after(last, recipe);
    chain.release(chain.tail());
    chain.release(last);
    node
}

/// Execute-and-erase through the public slot API.
fn erase<R>(chain: &Chain<R>, h: Handle) {
    chain.acquire(h);
    chain.begin_execution(h);
    chain.release(h);
    chain.acquire(h);
    chain.unlink(h);
    chain.release(h);
}

// ---------------------------------------------------------------------------
// Arena invariants
// ---------------------------------------------------------------------------

#[test]
fn free_list_reuse_keeps_the_arena_flat() {
    let chain: Chain<u64> = Chain::with_capacity(8);
    let cap0 = chain.arena_capacity();
    let mut reused_indices = std::collections::HashSet::new();
    for i in 0..5_000 {
        let h = append(&chain, i);
        reused_indices.insert(h.index());
        erase(&chain, h);
    }
    assert_eq!(
        chain.arena_capacity(),
        cap0,
        "steady-state execution must not grow the slab"
    );
    assert_eq!(
        reused_indices.len(),
        1,
        "a single-task steady state cycles one slot"
    );
    assert_eq!(chain.arena_recycled(), 4_999, "every alloc after the first reuses");
    assert!(chain.arena_high_water() <= 3, "2 sentinels + 1 live task");
    assert_eq!(chain.created(), 5_000);
    assert_eq!(chain.erased(), 5_000);
}

#[test]
fn generation_tags_catch_stale_handles() {
    let chain: Chain<u32> = Chain::new();
    let a = append(&chain, 1);
    assert!(!chain.stale(a));
    assert_eq!(chain.state(a), NodeState::Pending);
    erase(&chain, a);
    assert!(chain.stale(a), "erased ⇒ stale");
    assert_eq!(chain.next_validated(a), None, "no validated walk through it");
    assert_eq!(chain.with_recipe(a, |r| *r), None, "no validated recipe read");

    // Recycle the slot into a *different* task: the old handle must stay
    // stale even though the slot is live again — this is exactly the ABA
    // the generation tag kills.
    let b = append(&chain, 2);
    assert_eq!(b.index(), a.index(), "slot is recycled");
    assert_ne!(b.generation(), a.generation());
    assert!(chain.stale(a), "old incarnation stays dead");
    assert!(!chain.stale(b));
    assert_eq!(chain.with_recipe(b, |r| *r), Some(2));
}

#[test]
fn no_leak_after_teardown() {
    // Recipes are Arc clones of one sentinel value: every path — erased
    // tasks (freed at unlink), live tasks (freed when the chain drops),
    // free-list residents — must give its reference back.
    let canary = Arc::new(());
    {
        let chain: Chain<Arc<()>> = Chain::new();
        let mut live = Vec::new();
        for i in 0..100 {
            let h = append(&chain, canary.clone());
            if i % 2 == 0 {
                erase(&chain, h);
            } else {
                live.push(h);
            }
        }
        assert_eq!(
            Arc::strong_count(&canary),
            1 + live.len(),
            "erased nodes must drop their recipes at unlink, not at teardown"
        );
        drop(chain);
    }
    assert_eq!(Arc::strong_count(&canary), 1, "teardown leaks nothing");
}

#[test]
fn batched_append_is_equivalent_to_singles() {
    let singles: Chain<u32> = Chain::new();
    for i in 0..10 {
        append(&singles, i);
    }
    let batched: Chain<u32> = Chain::new();
    batched.acquire(batched.head());
    batched.acquire(batched.tail());
    let mut buf: Vec<u32> = (0..10).collect();
    batched.fill_tail(batched.head(), &mut buf);
    batched.release(batched.tail());
    batched.release(batched.head());

    assert_eq!(singles.validate().unwrap(), batched.validate().unwrap());
    assert_eq!(batched.tail_locks(), 1, "one lock for the whole batch");
    assert_eq!(singles.tail_locks(), 10);
}

// ---------------------------------------------------------------------------
// Multi-worker stress: trace identity across batch sizes
// ---------------------------------------------------------------------------

const STRESS_BATCHES: [u32; 3] = [1, 7, 64];

#[test]
fn stress_final_state_is_identical_at_batch_1_7_64() {
    let seed = 0xBA7C4;
    let tasks = 6_000;
    let expected = {
        let m = IncModel::new(tasks, 12);
        SequentialEngine::new(seed).run(&m);
        m.cells_snapshot()
    };
    for &batch in &STRESS_BATCHES {
        for &workers in &env_worker_counts() {
            let m = IncModel::new(tasks, 12);
            let report = ParallelEngine::new(ProtocolConfig {
                workers,
                tasks_per_cycle: 64, // C ≥ B: let every batch size bind
                batch,
                seed,
                ..Default::default()
            })
            .run(&m);
            assert_eq!(
                m.cells_snapshot(),
                expected,
                "B={batch} n={workers} diverged"
            );
            assert_eq!(report.totals.executed, tasks);
            assert_eq!(report.chain.batch, batch);
        }
    }
}

#[test]
fn stress_observation_traces_are_identical_at_batch_1_7_64() {
    // Epoch gating means batches must stop at epoch boundaries; a whole
    // trace comparison catches any batch that leaks across.
    let seed = 31;
    let tasks = 3_000;
    let trace = |workers: usize, batch: u32| {
        let m = IncModel::new(tasks, 8);
        let probe = || {
            vec![(
                "cells".to_string(),
                adapar::ObsValue::Series(
                    m.cells_snapshot().iter().map(|&c| c as f64).collect(),
                ),
            )]
        };
        let mut obs = Observer::new(230); // boundaries land mid-batch for B=64
        if workers == 0 {
            SequentialEngine::new(seed).run_observed(&m, &probe, &mut obs);
        } else {
            ParallelEngine::new(ProtocolConfig {
                workers,
                tasks_per_cycle: 64, // C ≥ B: let every batch size bind
                batch,
                seed,
                ..Default::default()
            })
            .run_observed(&m, &probe, &mut obs);
        }
        obs.finish().unwrap()
    };
    let reference = trace(0, 1);
    assert!(reference.len() > 10, "cadence must yield many frames");
    for &batch in &STRESS_BATCHES {
        for &workers in &env_worker_counts() {
            assert_eq!(
                trace(workers, batch),
                reference,
                "B={batch} n={workers} trace diverged"
            );
        }
    }
}

#[test]
fn stress_heavy_contention_across_batches() {
    // Single cell: every task conflicts with every other — the hardest
    // ordering regime. Batching must not reorder conflicting tasks.
    let seed = 5;
    let expected = {
        let m = IncModel::new(800, 1);
        SequentialEngine::new(seed).run(&m);
        m.cells_snapshot()
    };
    for &batch in &STRESS_BATCHES {
        let m = IncModel::new(800, 1);
        ParallelEngine::new(ProtocolConfig {
            workers: 4,
            tasks_per_cycle: 64, // C ≥ B: let every batch size bind
            batch,
            seed,
            ..Default::default()
        })
        .run(&m);
        assert_eq!(m.cells_snapshot(), expected, "B={batch} diverged");
    }
}

#[test]
fn batching_amortizes_tail_locks_by_an_order_of_magnitude() {
    let locks = |batch: u32| {
        let m = IncModel::new(8_000, 64);
        let report = ParallelEngine::new(ProtocolConfig {
            workers: 2,
            tasks_per_cycle: 64,
            batch,
            seed: 3,
            ..Default::default()
        })
        .run(&m);
        assert_eq!(report.totals.executed, 8_000);
        (report.chain.tail_locks, report.chain.tasks_per_tail_lock())
    };
    let (locks_1, per_1) = locks(1);
    let (locks_64, per_64) = locks(64);
    assert!(per_1 <= 1.0 + 1e-9, "B=1 links one task per lock");
    assert!(
        locks_64 * 10 <= locks_1,
        "B=64 must cut creation locks ≥10×: {locks_64} vs {locks_1}"
    );
    assert!(per_64 > 10.0, "B=64 must amortize >10 tasks/lock: {per_64}");
}

#[test]
fn env_pinned_batches_cover_the_ci_matrix() {
    // The CI conformance job pins ADAPAR_BATCH ∈ {1, 64}; locally both
    // run. Either way the helper must yield at least one legal size.
    let batches = env_batches();
    assert!(!batches.is_empty());
    assert!(batches.iter().all(|&b| b >= 1));
}
