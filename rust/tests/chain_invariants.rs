//! Chain structural invariants under concurrency, and deterministic
//! coverage of the worker's skip/pass paths via a gate model whose task
//! execution blocks until released.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use adapar::model::{Model, Record, TaskSource};
use adapar::protocol::{ParallelEngine, ProtocolConfig};
use adapar::sim::rng::TaskRng;
use adapar::sim::state::SharedSim;
use adapar::util::u32set::U32Set;

// Raw-chain concurrent stress lives in `chain::list`'s unit tests (the
// slot/link fields are crate-private by design); this file covers the
// protocol-level invariants reachable through the public API.

// ---------------------------------------------------------------------------
// Gate model: executions block on a condvar so the test can hold a task in
// `Executing` while a second worker walks past it — making the skip and
// pass counters deterministic even on a single-core host.
// ---------------------------------------------------------------------------

struct Gate {
    released: Mutex<bool>,
    cv: Condvar,
    /// Signals that a worker has entered the gated execution.
    entered: AtomicU64,
}

impl Gate {
    fn new() -> Self {
        Self {
            released: Mutex::new(false),
            cv: Condvar::new(),
            entered: AtomicU64::new(0),
        }
    }
    fn wait_released(&self) {
        let mut g = self.released.lock().unwrap();
        while !*g {
            g = self.cv.wait(g).unwrap();
        }
    }
    fn release(&self) {
        *self.released.lock().unwrap() = true;
        self.cv.notify_all();
    }
}

/// Task 0 blocks on the gate; tasks 1..4 touch cells so that task 1
/// conflicts with task 0 while tasks 2 and 3 are independent.
struct GateModel {
    gate: Arc<Gate>,
    cells: SharedSim<Vec<u64>>,
}

#[derive(Clone, Debug)]
struct GateRecipe {
    id: u32,
    cell: u32,
    gated: bool,
}

struct GateRecord {
    seen: U32Set,
}

impl Record for GateRecord {
    type Recipe = GateRecipe;
    fn depends(&self, r: &GateRecipe) -> bool {
        self.seen.contains(r.cell)
    }
    fn absorb(&mut self, r: &GateRecipe) {
        self.seen.insert(r.cell);
    }
    fn reset(&mut self) {
        self.seen.clear();
    }
}

struct GateSource {
    next: u32,
}

impl TaskSource for GateSource {
    type Recipe = GateRecipe;
    fn next_task(&mut self) -> Option<GateRecipe> {
        // Task layout: 0 gated on cell 0; 1 on cell 0 (conflicts with 0);
        // 2 on cell 1; 3 on cell 2 (independent).
        let r = match self.next {
            0 => GateRecipe { id: 0, cell: 0, gated: true },
            1 => GateRecipe { id: 1, cell: 0, gated: false },
            2 => GateRecipe { id: 2, cell: 1, gated: false },
            3 => GateRecipe { id: 3, cell: 2, gated: false },
            _ => return None,
        };
        self.next += 1;
        Some(r)
    }
}

impl Model for GateModel {
    type Recipe = GateRecipe;
    type Record = GateRecord;
    type Source = GateSource;
    fn source(&self, _seed: u64) -> GateSource {
        GateSource { next: 0 }
    }
    fn record(&self) -> GateRecord {
        GateRecord { seen: U32Set::new() }
    }
    fn execute(&self, r: &GateRecipe, _rng: &mut TaskRng) {
        if r.gated {
            self.gate.entered.fetch_add(1, Ordering::SeqCst);
            self.gate.wait_released();
        }
        unsafe {
            self.cells.get_mut()[r.cell as usize] += 1 + r.id as u64;
        }
    }
}

#[test]
fn second_worker_passes_executing_and_skips_dependent() {
    let gate = Arc::new(Gate::new());
    let model = GateModel {
        gate: gate.clone(),
        cells: SharedSim::new(vec![0; 3]),
    };

    // Releaser thread: waits until some worker is inside the gated task,
    // gives the other worker time to walk the chain past it, then opens
    // the gate.
    let releaser = {
        let gate = gate.clone();
        std::thread::spawn(move || {
            while gate.entered.load(Ordering::SeqCst) == 0 {
                std::thread::yield_now();
            }
            // Let the free worker make progress around the blocked one.
            std::thread::sleep(std::time::Duration::from_millis(120));
            gate.release();
        })
    };

    let report = ParallelEngine::new(ProtocolConfig {
        workers: 2,
        tasks_per_cycle: 6,
        seed: 0,
        ..Default::default()
    })
    .run(&model);
    releaser.join().unwrap();

    assert_eq!(report.totals.executed, 4);
    // While worker A hung inside task 0, worker B must have passed it
    // (absorbing cell 0) and therefore skipped task 1 (same cell) at least
    // once, then executed independent tasks 2/3.
    assert!(
        report.totals.passed_executing >= 1,
        "no worker passed the executing task: {report:?}"
    );
    assert!(
        report.totals.skipped_dependent >= 1,
        "no worker skipped the dependent task: {report:?}"
    );
    // Cell arithmetic: task0 (+1) then task1 (+2) on cell 0; +3 on cell 1;
    // +4 on cell 2.
    assert_eq!(unsafe { model.cells.get() }.clone(), vec![3, 3, 4]);
}

#[test]
fn gated_order_is_preserved_for_conflicting_tasks() {
    // Task 1 must observe task 0's write despite task 0 blocking for a
    // while: cell 0 ends at 3 only if 0 ran before 1.
    for _ in 0..3 {
        let gate = Arc::new(Gate::new());
        let model = GateModel {
            gate: gate.clone(),
            cells: SharedSim::new(vec![0; 3]),
        };
        let releaser = {
            let gate = gate.clone();
            std::thread::spawn(move || {
                while gate.entered.load(Ordering::SeqCst) == 0 {
                    std::thread::yield_now();
                }
                gate.release();
            })
        };
        ParallelEngine::new(ProtocolConfig {
            workers: 3,
            tasks_per_cycle: 2,
            seed: 1,
            ..Default::default()
        })
        .run(&model);
        releaser.join().unwrap();
        assert_eq!(unsafe { model.cells.get() }.clone(), vec![3, 3, 4]);
    }
}
