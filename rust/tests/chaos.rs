//! Chaos-harness acceptance suite (DESIGN.md §10).
//!
//! Two statements, end to end:
//!
//! 1. **The contract holds.** A seed sweep (`ADAPAR_SOAK_SEEDS` bounds
//!    the depth on PR gates; the nightly CI soak goes wider via
//!    `cli soak --seeds 32`) over the bundled fault plans and three
//!    sharded-capable registry models stays byte-identical to the
//!    sequential oracle on both injected engines.
//! 2. **The harness would catch a breach.** A deliberately-broken
//!    test-only engine variant — the real virtual engine, except a
//!    stall on one specific worker flips its RNG seeding, emulating a
//!    fault-dependent scheduling bug — is caught by the invariant
//!    checkers, shrunk by ddmin to exactly the triggering fault, and
//!    the emitted repro TOML parses back and still reproduces.

use adapar::api::registry::{self, BuildCtx};
use adapar::api::{DynModel, Observations, Observer};
use adapar::chaos::plan::bundled_plan;
use adapar::chaos::{invariant, soak, FaultHook, FaultPlan, Invariant, Violation};
use adapar::model::testkit::env_soak_seeds;
use adapar::protocol::ProtocolConfig;
use adapar::vtime::CostModel;

// ---------------------------------------------------------------- sweep

#[test]
fn seed_sweep_is_byte_identical_across_models_and_plans() {
    let seeds = env_soak_seeds(4);
    let cfg = soak::SoakConfig {
        models: vec!["sir".into(), "voter".into(), "ising".into()],
        seeds,
        workers: 3,
        ..Default::default()
    };
    let plans = cfg.plans.len() as u64;
    let report = soak::run(&cfg).unwrap();
    assert_eq!(report.runs, 3 * seeds * plans, "full grid covered");
    assert!(report.ok(), "{}", report.summary());
}

#[test]
fn soak_rejects_models_without_a_sharded_form() {
    let cfg = soak::SoakConfig {
        models: vec!["no-such-model".into()],
        seeds: 1,
        ..Default::default()
    };
    assert!(soak::run(&cfg).is_err(), "unknown model must not pass silently");
}

// ------------------------------------------------- broken engine variant

/// Simulation seed of the broken-variant scenario (arbitrary, fixed).
const SIM_SEED: u64 = 7;
/// The worker whose injected stall trips the planted bug.
const BUG_WORKER: usize = 1;

fn build_sir(seed: u64) -> Box<dyn DynModel> {
    registry::build(
        "sir",
        &BuildCtx {
            size: 2,
            agents: 300,
            steps: 60,
            seed,
            layout: Default::default(),
            params: Default::default(),
        },
    )
    .unwrap()
}

fn oracle() -> Observations {
    let m = build_sir(SIM_SEED);
    let mut obs = Observer::new(15);
    m.run_sequential(SIM_SEED, adapar::TraceMode::Off, Some(&mut obs));
    obs.finish().unwrap()
}

fn bug_triggered(p: &FaultPlan) -> bool {
    p.stalls.iter().any(|s| s.worker == BUG_WORKER)
}

/// The deliberately-broken test-only engine variant: dispatches to the
/// real virtual engine, but a plan stalling [`BUG_WORKER`] flips the
/// run's RNG seeding — the signature of a bug that only one injected
/// schedule exposes. Returns every violation the harness raises.
fn broken_engine_violations(p: &FaultPlan, reference: &Observations) -> Vec<Violation> {
    let exec_seed = if bug_triggered(p) { SIM_SEED + 1 } else { SIM_SEED };
    let m = build_sir(SIM_SEED);
    let mut hook = FaultHook::new(p.clone());
    let mut obs = Observer::new(15);
    let cfg = ProtocolConfig {
        workers: 3,
        seed: exec_seed,
        ..Default::default()
    };
    let report = m.run_virtual_chaos(&cfg, &CostModel::default(), Some(&mut obs), &mut hook);
    let mut out = invariant::check_run(
        "broken-sir virtual n=3",
        reference,
        &obs.finish().unwrap(),
        &report,
    );
    out.extend(hook.take_violations());
    out
}

#[test]
fn broken_engine_is_caught_shrunk_and_reproduced() {
    let reference = oracle();

    // The clean variant (bug dormant) passes: no crying wolf.
    let benign = FaultPlan::new("benign", 99).stall(0, 1, 10_000.0);
    assert!(
        broken_engine_violations(&benign, &reference).is_empty(),
        "a non-triggering plan must stay green"
    );

    // A wide plan containing the triggering stall is caught.
    let wide = FaultPlan::new("wide", 99)
        .stall(0, 1, 10_000.0)
        .stall(BUG_WORKER, 2, 25_000.0)
        .stall(2, 3, 40_000.0)
        .skew(0, 4.0)
        .jitter(100.0)
        .fence_delay(5_000);
    let violations = broken_engine_violations(&wide, &reference);
    assert!(!violations.is_empty(), "the planted bug must be caught");
    assert!(
        violations
            .iter()
            .any(|v| v.invariant == Invariant::TraceIdentity),
        "divergence must surface as a trace-identity violation: {violations:?}"
    );

    // ddmin shrinks the plan to exactly the triggering fault.
    let shrunk = soak::shrink(&wide, |cand| {
        !broken_engine_violations(cand, &reference).is_empty()
    });
    assert_eq!(shrunk.fault_count(), 1, "1-minimal repro: {shrunk:?}");
    assert_eq!(shrunk.stalls.len(), 1);
    assert_eq!(shrunk.stalls[0].worker, BUG_WORKER);
    assert!(shrunk.cost_skew.is_empty());
    assert_eq!(shrunk.order_jitter_ns, 0.0);
    assert_eq!(shrunk.fence_delay_ns, 0);

    // The repro TOML is committable: it parses back as-is (comment
    // header included) and the parsed plan still reproduces the bug.
    let toml = soak::repro_toml("sir", SIM_SEED, 3, &shrunk, &violations);
    let parsed = FaultPlan::from_toml(&toml).unwrap();
    assert_eq!(parsed, shrunk);
    assert!(
        !broken_engine_violations(&parsed, &reference).is_empty(),
        "the minimized repro must still fail"
    );
}

// -------------------------------------------------------- bundled plans

#[test]
fn bundled_plans_resolve_by_name_and_validate() {
    for name in ["stalls", "skew", "jitter"] {
        let p = bundled_plan(name).expect(name);
        assert_eq!(p.name, name);
        p.validate().unwrap();
        assert!(p.fault_count() > 0, "bundled plan `{name}` must inject");
    }
    assert!(bundled_plan("nope").is_none());
}
