//! Config system + CLI surface tests (the launcher layer).

use adapar::coordinator::config::{EngineKind, SweepConfig};
use adapar::coordinator::report::{figure_pivot, long_table};
use adapar::coordinator::run_sweep;
use adapar::util::cli::{Args, CliError, Spec};

const SPEC: Spec = Spec {
    options: &["model", "engine", "workers", "sizes"],
    flags: &["paper-scale"],
};

fn toks(s: &str) -> Vec<String> {
    s.split_whitespace().map(String::from).collect()
}

#[test]
fn cli_parses_figure_style_invocation() {
    let a = Args::parse(
        toks("sweep --model sir --engine virtual --workers 1,2,3,4,5 --sizes 10,50,100 --paper-scale"),
        &SPEC,
    )
    .unwrap();
    assert_eq!(a.subcommand.as_deref(), Some("sweep"));
    assert_eq!(a.get_list::<usize>("workers", &[]).unwrap(), vec![1, 2, 3, 4, 5]);
    assert_eq!(a.get_list::<usize>("sizes", &[]).unwrap(), vec![10, 50, 100]);
    assert!(a.has_flag("paper-scale"));
}

#[test]
fn cli_rejects_typos() {
    assert!(matches!(
        Args::parse(toks("run --modle sir"), &SPEC),
        Err(CliError::Unknown(_))
    ));
}

#[test]
fn preset_configs_run_end_to_end_scaled() {
    // Take the fig presets, shrink the workload drastically, run the grid,
    // check the report shape.
    for preset in ["fig2", "fig3"] {
        let mut cfg = SweepConfig::preset(preset).unwrap();
        cfg.sizes.truncate(2);
        cfg.workers = vec![1, 2];
        cfg.seeds = vec![1];
        cfg.agents = 200;
        cfg.steps = if cfg.model == "sir" { 10 } else { 3_000 };
        cfg.engine = EngineKind::Virtual;
        let res = run_sweep(&cfg).unwrap();
        assert_eq!(res.points.len(), 4, "{preset}");
        let pivot = figure_pivot(&res);
        assert_eq!(pivot.len(), 2);
        let long = long_table(&res);
        assert_eq!(long.len(), 4);
    }
}

#[test]
fn experiment_toml_files_parse() {
    for f in ["experiments/fig2.toml", "experiments/fig3.toml"] {
        let cfg = SweepConfig::from_file(f)
            .unwrap_or_else(|e| panic!("{f}: {e:#}"));
        cfg.validate().unwrap();
        assert_eq!(cfg.workers, vec![1, 2, 3, 4, 5]);
        assert_eq!(cfg.seeds.len(), 5, "paper: five instances");
    }
}

#[test]
fn toml_roundtrip_of_all_fields() {
    let cfg = SweepConfig::from_toml(
        r#"
model = "voter"
engine = "parallel"
sizes = [1]
workers = [2, 4]
seeds = [9, 10]
tasks_per_cycle = 3
batch = 4
agents = 77
steps = 123
paper_scale = true
calibrate = true
"#,
    )
    .unwrap();
    assert_eq!(cfg.model, "voter");
    assert_eq!(cfg.engine, EngineKind::Parallel);
    assert_eq!(cfg.tasks_per_cycle, 3);
    assert_eq!(cfg.batch, 4);
    assert_eq!(cfg.agents, 77);
    assert_eq!(cfg.effective_agents(), 77);
    assert_eq!(cfg.effective_steps(), 123);
    assert!(cfg.paper_scale && cfg.calibrate);
}

#[test]
fn invalid_configs_are_rejected() {
    assert!(SweepConfig::from_toml("model = \"nope\"").is_err());
    assert!(SweepConfig::from_toml("engine = \"nope\"").is_err());
    assert!(SweepConfig::from_toml("workers = []").is_err());
    assert!(SweepConfig::from_toml("batch = 0").is_err());
    assert!(SweepConfig::from_toml("model = \"ising\"\nengine = \"stepwise\"").is_err());
}
