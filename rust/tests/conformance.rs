//! Cross-engine conformance matrix (ISSUE 4): every model in the
//! registry × every engine it supports × worker counts × seeds must
//! produce the **same epoch observation trace** as the sequential
//! engine — frames are taken only at drained quiescent boundaries, so
//! trace equality is the facade-level statement of byte-identical state
//! evolution (DESIGN.md §6a).
//!
//! The matrix is driven through `registry::models()` and
//! `ModelInfo::supports`, so any future model registration is covered
//! automatically (asserted below by registering one at runtime). It
//! subsumes — without replacing — the per-model assertions in
//! `rust/tests/sharded.rs` and `rust/tests/observe.rs`.
//!
//! CI runs this suite once per worker count (`ADAPAR_SHARDED_WORKERS`
//! pins the count for the matrix job) and once per creation batch size
//! (`ADAPAR_BATCH` ∈ {1, 64} — the arena-chain batching knob must be
//! invisible in every trace); locally, all of 1/2/4 × {1, 64} run.

use adapar::api::registry::{self, Params};
use adapar::model::testkit::{env_batches, env_worker_counts as worker_counts, IncModel};
use adapar::{EngineKind, ModelInfo, ObsValue, Runnable, SimOutcome, Simulation};

const SEEDS: [u64; 2] = [11, 29];

/// Shrunk per-model workload: conformance is about equality, not timing,
/// so cap the registry defaults at a few thousand tasks. Works for any
/// future registration too (everything derives from its `ModelInfo`).
fn workload(info: &ModelInfo) -> (usize, u64, usize) {
    let agents = info.default_agents.clamp(1, 360);
    let steps = info.validate_steps.clamp(1, 4_000);
    let size = info.default_sizes.first().copied().unwrap_or(1).min(25);
    (agents, steps, size)
}

fn run(
    info: &ModelInfo,
    engine: EngineKind,
    workers: usize,
    batch: u32,
    seed: u64,
    every: u64,
    params: &Params,
) -> SimOutcome {
    let (agents, steps, size) = workload(info);
    Simulation::builder()
        .model(info.name.clone())
        .engine(engine)
        .workers(workers)
        // The effective batch is min(B, remaining C): raise C alongside
        // deep batches so the B = 64 axis genuinely exercises them.
        .tasks_per_cycle(batch.max(6))
        .batch(batch)
        .agents(agents)
        .steps(steps)
        .size(size)
        .seed(seed)
        .params(params.clone())
        .every(every)
        .run()
        .unwrap_or_else(|e| {
            panic!("{}/{engine} n={workers} B={batch} seed={seed}: {e}", info.name)
        })
}

/// Parameter variants per model: the registry defaults for everyone,
/// plus the bounded-relocation Schelling the sharded engine is built
/// for (ISSUE 4's acceptance workload).
fn variants(info: &ModelInfo) -> Vec<(&'static str, Params)> {
    let mut out = vec![("defaults", Params::new())];
    if info.name == "schelling" {
        let mut bounded = Params::new();
        bounded.set("move_radius", 2i64);
        out.push(("move_radius=2", bounded));
    }
    out
}

/// The matrix body for one model: sequential reference trace (at a
/// cadence yielding several frames) vs every supported engine × worker
/// count × seed.
fn assert_model_conforms(info: &ModelInfo) {
    for (label, params) in variants(info) {
        for &seed in &SEEDS {
            // Size the cadence from an unobserved sequential run so the
            // trace has ~4 frames regardless of the model's task shape.
            let total = run(info, EngineKind::Sequential, 1, 1, seed, 0, &params)
                .report
                .chain
                .tasks_executed;
            assert!(total > 0, "{}: empty workload", info.name);
            let every = (total / 4).max(1);
            let reference =
                run(info, EngineKind::Sequential, 1, 1, seed, every, &params).observable;
            assert!(
                reference.len() > 2,
                "{} [{label}]: cadence {every} must yield a multi-frame trace",
                info.name
            );
            for &engine in &EngineKind::ALL {
                if engine == EngineKind::Sequential || !info.supports(engine) {
                    continue;
                }
                // The batch axis only exercises the chain engines; the
                // chainless ones (stepwise, virtual) accept-and-ignore
                // the knob, so one batch value suffices for them.
                let batches = match engine {
                    EngineKind::Parallel | EngineKind::Sharded => env_batches(),
                    _ => vec![1],
                };
                for &workers in &worker_counts() {
                    for &batch in &batches {
                        let got =
                            run(info, engine, workers, batch, seed, every, &params).observable;
                        assert_eq!(
                            got, reference,
                            "{} [{label}] {engine} n={workers} B={batch} seed={seed}: \
                             trace diverged",
                            info.name
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn every_registered_model_conforms_on_every_supported_engine() {
    let infos = registry::models();
    assert!(infos.len() >= 5, "bundled models must be registered");
    for info in &infos {
        assert_model_conforms(info);
    }
}

#[test]
fn sharded_lattice_models_are_covered_by_the_matrix() {
    // ISSUE 4's acceptance: ising and bounded-relocation schelling run
    // sharded and byte-identical. The matrix above covers them because
    // the registry says so — pin that fact here so a capability
    // regression fails loudly instead of silently shrinking the matrix.
    for name in ["ising", "schelling"] {
        let info = registry::info(name).unwrap();
        assert!(
            info.supports(EngineKind::Sharded),
            "{name} must be sharded-capable"
        );
        assert!(info.engines().contains(&"sharded"), "{name}");
    }
}

#[test]
fn telemetry_modes_are_invisible_in_every_trace() {
    // ISSUE 7's conformance axis: the telemetry sampling layer must be
    // semantically inert — the epoch trace is byte-identical with rings
    // on, off, or saturated down to 4 slots, on every chain engine.
    // (`ADAPAR_TELEMETRY_MODES` pins the axis for CI sharding.)
    use adapar::model::testkit::env_telemetry_modes;
    use adapar::TelemetryMode;
    for name in ["voter", "sir"] {
        let info = registry::info(name).unwrap();
        let (agents, steps, size) = workload(&info);
        let run = |engine: EngineKind, workers: usize, mode: TelemetryMode| {
            Simulation::builder()
                .model(info.name.clone())
                .engine(engine)
                .workers(workers)
                .tasks_per_cycle(8)
                .batch(8)
                .agents(agents)
                .steps(steps)
                .size(size)
                .seed(17)
                .every(256)
                .telemetry(mode)
                .run()
                .unwrap_or_else(|e| {
                    panic!("{name}/{engine} n={workers} telemetry={}: {e}", mode.label())
                })
                .observable
        };
        let reference = run(EngineKind::Sequential, 1, TelemetryMode::On);
        assert!(reference.len() > 1, "{name}: need a multi-frame trace");
        for mode in env_telemetry_modes() {
            for &engine in &[EngineKind::Parallel, EngineKind::Sharded] {
                if !info.supports(engine) {
                    continue;
                }
                for &workers in &worker_counts() {
                    assert_eq!(
                        run(engine, workers, mode),
                        reference,
                        "{name} {engine} n={workers} telemetry={}: trace diverged",
                        mode.label()
                    );
                }
            }
        }
    }
}

#[test]
fn trace_modes_are_invisible_in_every_trace() {
    // ISSUE 8's conformance axis: causal tracing must be semantically
    // inert — the epoch observation trace is byte-identical with
    // tracing off, spans-only, or full causal recording, on every chain
    // engine × worker count. Only the report's `trace` timeline may
    // differ. (`ADAPAR_TRACE_MODES` pins the axis for CI sharding.)
    use adapar::model::testkit::env_trace_modes;
    use adapar::TraceMode;
    for name in ["voter", "sir"] {
        let info = registry::info(name).unwrap();
        let (agents, steps, size) = workload(&info);
        let run = |engine: EngineKind, workers: usize, mode: TraceMode| {
            Simulation::builder()
                .model(info.name.clone())
                .engine(engine)
                .workers(workers)
                .tasks_per_cycle(8)
                .batch(8)
                .agents(agents)
                .steps(steps)
                .size(size)
                .seed(23)
                .every(256)
                .trace(mode)
                .run()
                .unwrap_or_else(|e| {
                    panic!("{name}/{engine} n={workers} trace={}: {e}", mode.label())
                })
        };
        let reference = run(EngineKind::Sequential, 1, TraceMode::Off).observable;
        assert!(reference.len() > 1, "{name}: need a multi-frame trace");
        for mode in env_trace_modes() {
            for &engine in &[EngineKind::Sequential, EngineKind::Parallel, EngineKind::Sharded] {
                if !info.supports(engine) {
                    continue;
                }
                for &workers in &worker_counts() {
                    let out = run(engine, workers, mode);
                    assert_eq!(
                        out.observable, reference,
                        "{name} {engine} n={workers} trace={}: trace diverged",
                        mode.label()
                    );
                    // The timeline itself appears exactly when asked for.
                    assert_eq!(
                        out.report.trace.is_some(),
                        mode != TraceMode::Off,
                        "{name} {engine} n={workers} trace={}",
                        mode.label()
                    );
                }
            }
        }
    }
}

#[test]
fn streaming_windows_are_invisible_in_every_trace() {
    // ISSUE 10's conformance axis: the streaming materialization window
    // must be semantically inert back-pressure — the epoch observation
    // trace is byte-identical materialized, through the degenerate
    // one-task window, an awkward prime, and a deep window, on every
    // chain engine × worker count; only peak arena residency may
    // change. (`ADAPAR_STREAM_WINDOWS` pins the axis for CI sharding.)
    use adapar::model::testkit::env_stream_windows;
    for name in ["voter", "sir"] {
        let info = registry::info(name).unwrap();
        let (agents, steps, size) = workload(&info);
        let run = |engine: EngineKind, workers: usize, window: u64| {
            Simulation::builder()
                .model(info.name.clone())
                .engine(engine)
                .workers(workers)
                .tasks_per_cycle(8)
                .batch(8)
                .agents(agents)
                .steps(steps)
                .size(size)
                .seed(31)
                .every(256)
                .window(window)
                .run()
                .unwrap_or_else(|e| panic!("{name}/{engine} n={workers} W={window}: {e}"))
        };
        let reference = run(EngineKind::Sequential, 1, 0).observable;
        assert!(reference.len() > 1, "{name}: need a multi-frame trace");
        for window in env_stream_windows() {
            for &engine in &[
                EngineKind::Sequential,
                EngineKind::Parallel,
                EngineKind::Sharded,
                EngineKind::Virtual,
            ] {
                if !info.supports(engine) {
                    continue;
                }
                for &workers in &worker_counts() {
                    let out = run(engine, workers, window);
                    assert_eq!(
                        out.observable, reference,
                        "{name} {engine} n={workers} W={window}: trace diverged"
                    );
                    // The bound the window buys: never more than W live
                    // tasks (+2 arena sentinel slots) at once. Tight
                    // only on the single-chain engines — the sharded
                    // report *sums* per-shard high-waters (each with
                    // its own sentinels and epoch fences).
                    if window > 0 && matches!(engine, EngineKind::Parallel | EngineKind::Virtual) {
                        assert!(
                            out.report.chain.arena_high_water as u64 <= window + 2,
                            "{name} {engine} n={workers} W={window}: high-water {} escaped",
                            out.report.chain.arena_high_water
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn state_layouts_are_invisible_in_every_trace() {
    // ISSUE 9's conformance axis: the state layout is pure storage —
    // the epoch observation trace is byte-identical whether agent state
    // lives in the legacy AoS buffers, the bit-packed SoA words with
    // locality relabeling, or the bit-packed linear (identity-order)
    // words, on every engine × worker count. The reference is always
    // sequential-on-legacy, so this also pins packed against the
    // pre-SoA semantics. (`ADAPAR_LAYOUTS` pins the axis for CI
    // sharding.)
    use adapar::model::testkit::env_layouts;
    use adapar::Layout;
    for name in ["voter", "sir", "ising"] {
        let info = registry::info(name).unwrap();
        let (agents, steps, size) = workload(&info);
        let run = |engine: EngineKind, workers: usize, layout: Layout| {
            Simulation::builder()
                .model(info.name.clone())
                .engine(engine)
                .workers(workers)
                .tasks_per_cycle(8)
                .batch(8)
                .agents(agents)
                .steps(steps)
                .size(size)
                .seed(19)
                .every(256)
                .layout(layout)
                .run()
                .unwrap_or_else(|e| {
                    panic!("{name}/{engine} n={workers} layout={}: {e}", layout.label())
                })
                .observable
        };
        let reference = run(EngineKind::Sequential, 1, Layout::Legacy);
        assert!(reference.len() > 1, "{name}: need a multi-frame trace");
        for layout in env_layouts() {
            for &engine in &EngineKind::ALL {
                if !info.supports(engine) {
                    continue;
                }
                for &workers in &worker_counts() {
                    assert_eq!(
                        run(engine, workers, layout),
                        reference,
                        "{name} {engine} n={workers} layout={}: trace diverged",
                        layout.label()
                    );
                }
            }
        }
    }
}

#[test]
fn runtime_registrations_enter_the_matrix() {
    // A model registered at runtime — sharding capability included —
    // must be covered by exactly the same machinery, proving the matrix
    // extends to future models with zero test edits.
    registry::register(
        ModelInfo::new("conformance-probe", "runtime-registered matrix probe")
            .agents(24, 24)
            .steps(600, 600)
            .validate_steps(600)
            .sharded(),
        |ctx| {
            Ok(Runnable::new(
                "conformance-probe",
                IncModel::new(ctx.steps.max(1), 24),
            )
            .observed(|m| {
                vec![(
                    "cells".to_string(),
                    ObsValue::Series(m.cells_snapshot().iter().map(|&c| c as f64).collect()),
                )]
            })
            .with_sharding()
            .boxed())
        },
    )
    .expect("fresh name registers");
    let info = registry::models()
        .into_iter()
        .find(|i| i.name == "conformance-probe")
        .expect("registry-driven iteration sees the new model");
    assert!(info.supports(EngineKind::Sharded));
    assert_model_conforms(&info);
}
