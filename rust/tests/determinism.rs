//! The protocol's central correctness property (DESIGN.md §6):
//! **parallel execution is bit-identical to sequential execution** — for
//! every model, every seed, every worker count, every C — and the virtual
//! testbed reproduces the same states.

use adapar::models::axelrod::{AxelrodModel, AxelrodParams};
use adapar::models::ising::{IsingModel, IsingParams};
use adapar::models::sir::{SirModel, SirParams};
use adapar::models::voter::{VoterModel, VoterParams};
use adapar::protocol::{ParallelEngine, ProtocolConfig, SequentialEngine};
use adapar::sim::graph::watts_strogatz;
use adapar::sim::rng::Rng;
use adapar::vtime::{CostModel, VirtualEngine};

fn cfg(workers: usize, seed: u64, c: u32) -> ProtocolConfig {
    ProtocolConfig {
        workers,
        tasks_per_cycle: c,
        seed,
        ..Default::default()
    }
}

#[test]
fn axelrod_all_engines_agree() {
    let params = AxelrodParams {
        agents: 80,
        features: 15,
        traits: 3,
        omega: 0.95,
        steps: 6_000,
    };
    for seed in [1u64, 42, 0xDEAD] {
        let reference = {
            let m = AxelrodModel::new(params, seed);
            SequentialEngine::new(seed).run(&m);
            m.snapshot()
        };
        for workers in [1, 2, 3, 5] {
            let m = AxelrodModel::new(params, seed);
            ParallelEngine::new(cfg(workers, seed, 6)).run(&m);
            assert_eq!(m.snapshot(), reference, "parallel n={workers} seed={seed}");
        }
        for workers in [2, 4] {
            let m = AxelrodModel::new(params, seed);
            VirtualEngine {
                workers,
                tasks_per_cycle: 6,
                seed,
                cost: CostModel::default(),
                trace: adapar::TraceMode::Off,
                window: 0,
            }
            .run(&m);
            assert_eq!(m.snapshot(), reference, "virtual n={workers} seed={seed}");
        }
    }
}

#[test]
fn sir_all_engines_agree_across_granularities() {
    for s in [10usize, 25, 100] {
        let params = SirParams::scaled(s, 400, 60);
        let seed = 7;
        let reference = {
            let m = SirModel::new(params, seed);
            SequentialEngine::new(seed).run(&m);
            m.snapshot()
        };
        for workers in [1, 2, 4] {
            let m = SirModel::new(params, seed);
            ParallelEngine::new(cfg(workers, seed, 6)).run(&m);
            assert_eq!(m.snapshot(), reference, "parallel s={s} n={workers}");
        }
        let m = SirModel::new(params, seed);
        VirtualEngine {
            workers: 3,
            tasks_per_cycle: 6,
            seed,
            cost: CostModel::default(),
            trace: adapar::TraceMode::Off,
            window: 0,
        }
        .run(&m);
        assert_eq!(m.snapshot(), reference, "virtual s={s}");
    }
}

#[test]
fn voter_on_small_world_graph_agrees() {
    let seed = 11;
    let make = || {
        let mut rng = Rng::new(77);
        let g = watts_strogatz(150, 6, 0.1, &mut rng);
        VoterModel::new(g, VoterParams { opinions: 4, steps: 10_000 }, 3)
    };
    let reference = {
        let m = make();
        SequentialEngine::new(seed).run(&m);
        m.snapshot()
    };
    for workers in [2, 3, 4] {
        let m = make();
        ParallelEngine::new(cfg(workers, seed, 6)).run(&m);
        assert_eq!(m.snapshot(), reference, "n={workers}");
    }
}

#[test]
fn ising_agrees_across_c_values() {
    let params = IsingParams {
        side: 10,
        temperature: 2.3,
        steps: 8_000,
    };
    let seed = 23;
    let reference = {
        let m = IsingModel::new(params, 9);
        SequentialEngine::new(seed).run(&m);
        m.snapshot()
    };
    for c in [1u32, 2, 6, 32] {
        let m = IsingModel::new(params, 9);
        ParallelEngine::new(cfg(3, seed, c)).run(&m);
        assert_eq!(m.snapshot(), reference, "C={c}");
    }
}

#[test]
fn repeated_parallel_runs_are_identical() {
    // The parallel engine's *scheduling* is nondeterministic; its *result*
    // must not be. Run the same configuration repeatedly.
    let params = SirParams::scaled(20, 300, 50);
    let seed = 31;
    let first = {
        let m = SirModel::new(params, 1);
        ParallelEngine::new(cfg(4, seed, 6)).run(&m);
        m.snapshot()
    };
    for run in 0..4 {
        let m = SirModel::new(params, 1);
        ParallelEngine::new(cfg(4, seed, 6)).run(&m);
        assert_eq!(m.snapshot(), first, "run {run} diverged");
    }
}

#[test]
fn different_seeds_differ() {
    let params = AxelrodParams {
        agents: 50,
        features: 10,
        traits: 3,
        omega: 0.95,
        steps: 4_000,
    };
    let snap = |seed: u64| {
        let m = AxelrodModel::new(params, 0);
        ParallelEngine::new(cfg(2, seed, 6)).run(&m);
        m.snapshot()
    };
    assert_ne!(snap(1), snap(2), "seeds must matter");
}
