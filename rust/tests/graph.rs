//! Unit/property tests for the `sim/graph` toolkit: generator structural
//! invariants (symmetric adjacency, degree sums, no self-loops), the
//! aggregate (quotient) graph's exactness, and partition invariants —
//! including the BFS edge-cut partitioner feeding the sharded scheduler.

use adapar::sim::graph::{
    aggregate_graph, bfs_partition, complete, contiguous_partition, edge_cut, erdos_renyi,
    grid_partition, lattice2d, ring_lattice, round_robin_partition, watts_strogatz, Csr,
    Partition,
};
use adapar::sim::rng::Rng;
use adapar::util::prop::{check, ranged_f64, ranged_usize, Config, Gen, PairOf};

/// Structural invariants every generator must satisfy: symmetric
/// neighbour lists, degree sum = 2m, sorted unique neighbours, no
/// self-loops.
fn assert_well_formed(g: &Csr) {
    let mut degree_sum = 0usize;
    for (v, nbrs) in g.iter() {
        degree_sum += nbrs.len();
        for w in nbrs.windows(2) {
            assert!(w[0] < w[1], "neighbours of {v} not sorted-unique");
        }
        for &u in nbrs {
            assert_ne!(u as usize, v, "self-loop at {v}");
            assert!(
                g.neighbors(u as usize).contains(&(v as u32)),
                "edge {v}->{u} not symmetric"
            );
        }
    }
    assert_eq!(degree_sum, 2 * g.m(), "degree sum must be twice the edges");
}

/// Partition invariants: every vertex in exactly one block, members
/// agree with block_of, no empty blocks, dense block ids.
fn assert_valid_partition(p: &Partition, n: usize) {
    assert_eq!(p.n(), n);
    let mut seen = vec![false; n];
    for b in 0..p.blocks() {
        assert!(!p.members(b).is_empty(), "block {b} empty");
        for &v in p.members(b) {
            assert_eq!(p.block_of(v as usize), b as u32);
            assert!(!seen[v as usize], "vertex {v} in two blocks");
            seen[v as usize] = true;
        }
    }
    assert!(seen.iter().all(|&s| s), "vertex missing from all blocks");
}

#[test]
fn generators_produce_well_formed_graphs() {
    // Ring lattices across sizes/degrees (degree must stay even, < n).
    check(
        "ring lattice well-formed",
        Config::default(),
        PairOf(ranged_usize(8, 200), ranged_usize(1, 3)),
        |&(n, half)| {
            let g = ring_lattice(n, 2 * half);
            assert_well_formed(&g);
            g.n() == n && (0..n).all(|v| g.degree(v) == 2 * half)
        },
    );
    // Erdős–Rényi: exact edge count, well-formed.
    check(
        "erdos-renyi well-formed",
        Config {
            cases: 32,
            ..Default::default()
        },
        PairOf(ranged_usize(5, 60), ranged_usize(0, 40)),
        |&(n, m)| {
            let m = m.min(n * (n - 1) / 2);
            let g = erdos_renyi(n, m, &mut Rng::new((n * 31 + m) as u64));
            assert_well_formed(&g);
            g.n() == n && g.m() == m
        },
    );
    // Watts–Strogatz: rewiring must preserve well-formedness and stay
    // close to the ring's edge count (saturation may drop a few).
    check(
        "watts-strogatz well-formed",
        Config {
            cases: 32,
            ..Default::default()
        },
        PairOf(ranged_usize(10, 100), ranged_f64(0.0, 1.0)),
        |&(n, beta)| {
            let g = watts_strogatz(n, 4, beta, &mut Rng::new(n as u64));
            assert_well_formed(&g);
            g.n() == n && g.m() <= 2 * n + 8 && g.m() + 8 >= 2 * n
        },
    );
    let g = lattice2d(7);
    assert_well_formed(&g);
    assert_eq!(g.m(), 2 * 49);
    let g = complete(9);
    assert_well_formed(&g);
    assert_eq!(g.m(), 36);
}

#[test]
fn aggregate_graph_is_exactly_the_crossing_relation() {
    // Property: blocks p≠q are adjacent in the aggregate graph iff some
    // edge of g crosses them (checked by brute force), and the aggregate
    // itself is well-formed.
    check(
        "aggregate = crossing relation",
        Config {
            cases: 40,
            ..Default::default()
        },
        PairOf(ranged_usize(6, 60), ranged_usize(2, 6)),
        |&(n, blocks)| {
            let m = (n * 2).min(n * (n - 1) / 2);
            let g = erdos_renyi(n, m, &mut Rng::new(n as u64 * 7 + blocks as u64));
            let s = n.div_ceil(blocks);
            let p = contiguous_partition(n, s);
            let a = aggregate_graph(&g, &p);
            assert_well_formed(&a);
            assert_eq!(a.n(), p.blocks());
            for bp in 0..p.blocks() {
                for bq in 0..p.blocks() {
                    if bp == bq {
                        continue;
                    }
                    let crossing = g.iter().any(|(v, nbrs)| {
                        p.block_of(v) == bp as u32
                            && nbrs.iter().any(|&u| p.block_of(u as usize) == bq as u32)
                    });
                    if a.has_edge(bp, bq) != crossing {
                        return false;
                    }
                }
            }
            true
        },
    );
}

#[test]
fn aggregate_degree_is_bounded_by_block_count() {
    let g = ring_lattice(200, 8);
    let p = contiguous_partition(200, 20);
    let a = aggregate_graph(&g, &p);
    for b in 0..a.n() {
        assert!(a.degree(b) < a.n(), "quotient degree bound");
    }
    // Reach 4 < block size 20: each block touches exactly its two arc
    // neighbours.
    for b in 0..a.n() {
        assert_eq!(a.degree(b), 2);
    }
}

#[test]
fn partitions_satisfy_block_invariants() {
    check(
        "contiguous/round-robin/bfs partitions valid",
        Config {
            cases: 48,
            ..Default::default()
        },
        PairOf(ranged_usize(4, 120), ranged_usize(1, 8)),
        |&(n, k)| {
            let k = k.min(n);
            let contiguous = contiguous_partition(n, n.div_ceil(k));
            assert_valid_partition(&contiguous, n);
            let rr = round_robin_partition(n, k);
            assert_valid_partition(&rr, n);
            let g = ring_lattice(n.max(4), 2);
            let bfs = bfs_partition(&g, k.min(g.n()));
            assert_valid_partition(&bfs, g.n());
            assert_eq!(bfs.blocks(), k.min(g.n()));
            // Balance: BFS block sizes differ by at most one.
            let sizes: Vec<usize> = (0..bfs.blocks()).map(|b| bfs.members(b).len()).collect();
            sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1
        },
    );
}

#[test]
fn bfs_partition_cut_quality_on_local_topologies() {
    // On graphs with locality the BFS partitioner must not be worse than
    // the pessimal round-robin assignment.
    check(
        "bfs cut <= round robin cut",
        Config {
            cases: 32,
            ..Default::default()
        },
        PairOf(ranged_usize(16, 160), ranged_usize(2, 6)),
        |&(n, k)| {
            let g = ring_lattice(n, 4);
            let bfs = bfs_partition(&g, k);
            let rr = round_robin_partition(n, k);
            edge_cut(&g, &bfs) <= edge_cut(&g, &rr)
        },
    );
}

/// The row range, column range and size of one grid-partition shard, in
/// unwrapped grid coordinates.
fn shard_box(p: &Partition, cols: usize, b: usize) -> (usize, usize, usize, usize, usize) {
    let rows_of: Vec<usize> = p.members(b).iter().map(|&v| v as usize / cols).collect();
    let cols_of: Vec<usize> = p.members(b).iter().map(|&v| v as usize % cols).collect();
    (
        *rows_of.iter().min().unwrap(),
        *rows_of.iter().max().unwrap(),
        *cols_of.iter().min().unwrap(),
        *cols_of.iter().max().unwrap(),
        p.members(b).len(),
    )
}

#[test]
fn grid_partition_shards_are_contiguous_rectangles() {
    // Every shard must be a *full* rectangle in unwrapped grid
    // coordinates — which implies 4-neighbour contiguity without even
    // using the torus wrap (ISSUE 4's contiguity guarantee).
    check(
        "grid shards are full rectangles",
        Config {
            cases: 48,
            ..Default::default()
        },
        PairOf(ranged_usize(4, 24), ranged_usize(1, 8)),
        |&(side, parts)| {
            let p = grid_partition(side, side, parts);
            assert_valid_partition(&p, side * side);
            assert_eq!(p.blocks(), parts);
            for b in 0..parts {
                let (r0, r1, c0, c1, size) = shard_box(&p, side, b);
                assert_eq!(
                    (r1 - r0 + 1) * (c1 - c0 + 1),
                    size,
                    "side={side} parts={parts}: shard {b} is not a full rectangle"
                );
            }
            true
        },
    );
}

#[test]
fn grid_partition_balances_within_stripes() {
    // Stripe heights differ by at most one row, and the widths of the
    // shards sharing a row stripe differ by at most one column — the
    // "balance within one row/column stripe" contract.
    check(
        "grid stripe balance",
        Config {
            cases: 48,
            ..Default::default()
        },
        PairOf(ranged_usize(4, 24), ranged_usize(1, 8)),
        |&(side, parts)| {
            let p = grid_partition(side, side, parts);
            let boxes: Vec<_> = (0..parts).map(|b| shard_box(&p, side, b)).collect();
            let heights: Vec<usize> = boxes.iter().map(|&(r0, r1, ..)| r1 - r0 + 1).collect();
            assert!(
                heights.iter().max().unwrap() - heights.iter().min().unwrap() <= 1,
                "side={side} parts={parts}: stripe heights {heights:?}"
            );
            for (i, &(r0, r1, c0, c1, _)) in boxes.iter().enumerate() {
                for &(s0, s1, d0, d1, _) in &boxes[i + 1..] {
                    if (r0, r1) == (s0, s1) {
                        let (w, v) = (c1 - c0 + 1, d1 - d0 + 1);
                        assert!(
                            w.abs_diff(v) <= 1,
                            "side={side} parts={parts}: widths {w} vs {v} in one stripe"
                        );
                    }
                }
            }
            true
        },
    );
}

#[test]
fn grid_partition_cut_never_exceeds_bfs_on_lattices() {
    // The lattice-native tiling must never lose to the generic BFS
    // growth on the topology it specializes — ISSUE 4's acceptance
    // property, over varied side lengths and shard counts.
    check(
        "grid cut <= bfs cut on lattice2d",
        Config {
            cases: 48,
            ..Default::default()
        },
        PairOf(ranged_usize(4, 24), ranged_usize(1, 8)),
        |&(side, parts)| {
            let g = lattice2d(side);
            let grid = grid_partition(side, side, parts);
            let bfs = bfs_partition(&g, parts);
            edge_cut(&g, &grid) <= edge_cut(&g, &bfs)
        },
    );
}

#[test]
fn edge_cut_extremes() {
    let g = ring_lattice(30, 2);
    assert_eq!(edge_cut(&g, &contiguous_partition(30, 30)), 0, "one block");
    assert_eq!(
        edge_cut(&g, &round_robin_partition(30, 30)),
        g.m(),
        "singleton blocks cut everything"
    );
}

/// A tiny custom generator exercising `Gen` directly: random graphs as
/// edge lists (the shrinker drops edges), validating `Csr::from_edges`
/// against its own accessors.
struct EdgeList {
    n: usize,
}

impl Gen for EdgeList {
    type Value = Vec<(u32, u32)>;
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        let mut set = std::collections::BTreeSet::new();
        let m = rng.index(2 * self.n);
        while set.len() < m {
            let (a, b) = rng.distinct_pair(self.n);
            set.insert((a.min(b) as u32, a.max(b) as u32));
        }
        set.into_iter().collect()
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        (0..v.len())
            .map(|i| {
                let mut c = v.clone();
                c.remove(i);
                c
            })
            .collect()
    }
}

#[test]
fn csr_roundtrips_arbitrary_edge_lists() {
    let n = 24;
    check(
        "csr roundtrip",
        Config {
            cases: 48,
            ..Default::default()
        },
        EdgeList { n },
        |edges| {
            let g = Csr::from_edges(n, edges);
            assert_well_formed(&g);
            g.m() == edges.len()
                && edges
                    .iter()
                    .all(|&(a, b)| g.has_edge(a as usize, b as usize))
        },
    );
}
