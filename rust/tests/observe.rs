//! The observation determinism contract (DESIGN.md §6a): at a fixed seed
//! the *whole epoch trace* — not just the final state — is byte-identical
//! across the sequential reference, the parallel engine at any worker
//! count, the stepwise baseline (where legal) and the virtual testbed.
//! Plus: epoch boundary math (partial last epoch, epoch longer than the
//! run) and the CSV/JSON-lines sinks.

use adapar::api::observe::{frame_count, ObsValue, Observations, ObservePlan};
use adapar::{EngineKind, Simulation};

/// SIR trace: 300 agents in blocks of 30 for 20 steps → 400 canonical
/// tasks (20 steps × 2 phases × 10 blocks).
fn sir_trace(engine: EngineKind, workers: usize, every: u64) -> Observations {
    Simulation::builder()
        .model("sir")
        .engine(engine)
        .workers(workers)
        .agents(300)
        .steps(20)
        .size(30)
        .seed(9)
        .every(every)
        .run()
        .unwrap()
        .observable
}

#[test]
fn sir_trace_is_byte_identical_across_all_engines() {
    // 37 does not divide 400: the trace ends on a partial epoch.
    let reference = sir_trace(EngineKind::Sequential, 1, 37);
    assert_eq!(reference.len() as u64, frame_count(37, 400));
    assert_eq!(reference.frames[0].tasks, 0);
    assert_eq!(reference.frames[1].tasks, 37);
    assert_eq!(reference.final_frame().unwrap().tasks, 400);
    for workers in [1, 2, 4] {
        assert_eq!(
            sir_trace(EngineKind::Parallel, workers, 37),
            reference,
            "parallel n={workers}"
        );
    }
    for workers in [1, 2, 3] {
        assert_eq!(
            sir_trace(EngineKind::Stepwise, workers, 37),
            reference,
            "stepwise n={workers}"
        );
    }
    assert_eq!(sir_trace(EngineKind::Virtual, 2, 37), reference, "virtual");
    assert_eq!(sir_trace(EngineKind::Virtual, 4, 37), reference, "virtual");
}

#[test]
fn axelrod_trace_is_byte_identical_across_engines() {
    let trace = |engine, workers| {
        Simulation::builder()
            .model("axelrod")
            .engine(engine)
            .workers(workers)
            .agents(60)
            .steps(3_000)
            .size(8)
            .seed(21)
            .observe(ObservePlan::every(500))
            .run()
            .unwrap()
            .observable
    };
    let reference = trace(EngineKind::Sequential, 1);
    assert_eq!(reference.len() as u64, frame_count(500, 3_000), "7 frames");
    // The domain count is a real trajectory: it must move over the run.
    let domains: Vec<i64> = reference
        .series("domains")
        .iter()
        .map(|(_, v)| match v {
            ObsValue::Int(n) => *n,
            other => panic!("domains must be Int, got {other:?}"),
        })
        .collect();
    assert!(domains.windows(2).any(|w| w[0] != w[1]), "{domains:?}");
    for workers in [1, 2, 4] {
        assert_eq!(
            trace(EngineKind::Parallel, workers),
            reference,
            "parallel n={workers}"
        );
    }
    assert_eq!(trace(EngineKind::Virtual, 3), reference, "virtual");
}

#[test]
fn epoch_boundary_edge_cases() {
    // Epoch longer than the whole run: initial + final frame only.
    let t = sir_trace(EngineKind::Parallel, 2, 10_000);
    assert_eq!(
        t.frames.iter().map(|f| f.tasks).collect::<Vec<_>>(),
        vec![0, 400]
    );
    // Cadence dividing the total exactly: no duplicate final frame.
    let t = sir_trace(EngineKind::Parallel, 2, 100);
    assert_eq!(
        t.frames.iter().map(|f| f.tasks).collect::<Vec<_>>(),
        vec![0, 100, 200, 300, 400]
    );
    assert_eq!(t, sir_trace(EngineKind::Sequential, 1, 100));
    assert_eq!(t, sir_trace(EngineKind::Stepwise, 2, 100));
    // A boundary inside a phase (100-block steps would hide it): 37 is
    // covered by the main test; here the smallest awkward cadence.
    let t = sir_trace(EngineKind::Stepwise, 3, 7);
    assert_eq!(t, sir_trace(EngineKind::Sequential, 1, 7));
    assert_eq!(t.len() as u64, frame_count(7, 400));
}

#[test]
fn frames_conserve_population_and_are_monotone() {
    let t = sir_trace(EngineKind::Parallel, 4, 64);
    let mut last = None;
    for frame in &t.frames {
        if let Some(prev) = last {
            assert!(frame.tasks > prev, "task counts must increase");
        }
        last = Some(frame.tasks);
        match frame.get("census") {
            Some(ObsValue::Counts(c)) => {
                assert_eq!(c.iter().map(|(_, n)| n).sum::<i64>(), 300, "{frame}");
                assert_eq!(
                    c.iter().map(|(l, _)| l.as_str()).collect::<Vec<_>>(),
                    vec!["S", "I", "R"]
                );
            }
            other => panic!("expected census counts, got {other:?}"),
        }
    }
}

#[test]
fn csv_and_jsonl_sinks_stream_the_trace() {
    let dir = std::env::temp_dir().join("adapar_observe_sinks_test");
    let csv_path = dir.join("epidemic.csv");
    let jsonl_path = dir.join("epidemic.jsonl");
    let out = Simulation::builder()
        .model("sir")
        .engine(EngineKind::Parallel)
        .workers(2)
        .agents(300)
        .steps(20)
        .size(30)
        .seed(9)
        .observe(ObservePlan::every(100).csv(&csv_path).jsonl(&jsonl_path))
        .run()
        .unwrap();
    assert_eq!(out.observable.len(), 5);

    let csv = std::fs::read_to_string(&csv_path).unwrap();
    let table = adapar::util::csv::parse_csv(&csv).unwrap();
    assert_eq!(table.len(), out.observable.len(), "one row per frame");
    assert_eq!(table.col("tasks"), Some(0));
    assert_eq!(table.col("census.S"), Some(1));
    assert_eq!(table.col("census.I"), Some(2));
    assert_eq!(table.col("census.R"), Some(3));

    let jsonl = std::fs::read_to_string(&jsonl_path).unwrap();
    let lines: Vec<&str> = jsonl.lines().collect();
    assert_eq!(lines.len(), out.observable.len());
    assert!(lines[0].starts_with(r#"{"tasks":0,"census":{"S":"#), "{}", lines[0]);
    assert!(lines[4].contains(r#""tasks":400"#), "{}", lines[4]);
}

#[test]
fn unobserved_runs_still_yield_a_final_typed_frame() {
    for model in ["voter", "ising", "schelling"] {
        let out = Simulation::builder()
            .model(model)
            .engine(EngineKind::Sequential)
            .agents(if model == "ising" { 256 } else { 200 })
            .steps(500)
            .seed(3)
            .run()
            .unwrap();
        assert_eq!(out.observable.len(), 1, "{model}");
        let frame = out.observable.final_frame().unwrap();
        assert_eq!(frame.tasks, 500, "{model}");
        let expected = match model {
            "voter" => vec!["tally", "opinions"],
            "ising" => vec!["magnetization", "energy"],
            _ => vec!["segregation", "satisfied"],
        };
        assert_eq!(
            frame.values.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>(),
            expected,
            "{model}"
        );
    }
}

#[test]
fn voter_trace_deterministic_across_chain_engines() {
    let trace = |engine, workers| {
        Simulation::builder()
            .model("voter")
            .engine(engine)
            .workers(workers)
            .agents(150)
            .steps(2_000)
            .seed(11)
            .every(333)
            .run()
            .unwrap()
            .observable
    };
    let reference = trace(EngineKind::Sequential, 1);
    assert_eq!(reference.len() as u64, frame_count(333, 2_000));
    assert_eq!(trace(EngineKind::Parallel, 3), reference);
    assert_eq!(trace(EngineKind::Virtual, 2), reference);
}
