//! Property tests over the protocol engines using the in-crate
//! property-testing framework (`util::prop`): random workload shapes,
//! random seeds, random worker counts — parallel must always equal
//! sequential, counters must always balance.

use adapar::model::testkit::IncModel;
use adapar::protocol::{ParallelEngine, ProtocolConfig, SequentialEngine};
use adapar::util::prop::{check, ranged_usize, AnySeed, Config, Gen, PairOf};
use adapar::vtime::{CostModel, VirtualEngine};

/// Generator for (tasks, cells) workload shapes.
fn workload() -> PairOf<adapar::util::prop::RangedUsize, adapar::util::prop::RangedUsize> {
    PairOf(ranged_usize(1, 600), ranged_usize(1, 32))
}

#[test]
fn prop_parallel_equals_sequential() {
    let gen = PairOf(workload(), PairOf(AnySeed, ranged_usize(1, 5)));
    check(
        "parallel == sequential",
        Config { cases: 40, ..Default::default() },
        gen,
        |&((tasks, cells), (seed, workers))| {
            let expected = {
                let m = IncModel::new(tasks as u64, cells as u32);
                SequentialEngine::new(seed).run(&m);
                m.cells_snapshot()
            };
            let m = IncModel::new(tasks as u64, cells as u32);
            let rep = ParallelEngine::new(ProtocolConfig {
                workers,
                tasks_per_cycle: 6,
                seed,
                ..Default::default()
            })
            .run(&m);
            m.cells_snapshot() == expected && rep.totals.executed == tasks as u64
        },
    );
}

#[test]
fn prop_virtual_equals_sequential() {
    let gen = PairOf(workload(), PairOf(AnySeed, ranged_usize(1, 5)));
    check(
        "virtual == sequential",
        Config { cases: 40, ..Default::default() },
        gen,
        |&((tasks, cells), (seed, workers))| {
            let expected = {
                let m = IncModel::new(tasks as u64, cells as u32);
                SequentialEngine::new(seed).run(&m);
                m.cells_snapshot()
            };
            let m = IncModel::new(tasks as u64, cells as u32);
            let rep = VirtualEngine {
                workers,
                tasks_per_cycle: 6,
                seed,
                cost: CostModel::default(),
                trace: adapar::TraceMode::Off,
                window: 0,
            }
            .run(&m);
            m.cells_snapshot() == expected
                && rep.totals.executed == tasks as u64
                && rep.time_s > 0.0
        },
    );
}

#[test]
fn prop_c_parameter_never_changes_results() {
    let gen = PairOf(workload(), PairOf(AnySeed, ranged_usize(1, 64)));
    check(
        "result independent of C",
        Config { cases: 30, ..Default::default() },
        gen,
        |&((tasks, cells), (seed, c))| {
            let expected = {
                let m = IncModel::new(tasks as u64, cells as u32);
                SequentialEngine::new(seed).run(&m);
                m.cells_snapshot()
            };
            let m = IncModel::new(tasks as u64, cells as u32);
            ParallelEngine::new(ProtocolConfig {
                workers: 3,
                tasks_per_cycle: c as u32,
                seed,
                ..Default::default()
            })
            .run(&m);
            m.cells_snapshot() == expected
        },
    );
}

#[test]
fn prop_counters_balance() {
    let gen = PairOf(workload(), ranged_usize(1, 4));
    check(
        "created == executed == tasks",
        Config { cases: 30, ..Default::default() },
        gen,
        |&((tasks, cells), workers)| {
            let m = IncModel::new(tasks as u64, cells as u32);
            let rep = ParallelEngine::new(ProtocolConfig {
                workers,
                tasks_per_cycle: 6,
                seed: 1,
                ..Default::default()
            })
            .run(&m);
            let per_worker_sum: u64 = rep.per_worker.iter().map(|w| w.executed).sum();
            rep.totals.created == tasks as u64
                && rep.totals.executed == tasks as u64
                && per_worker_sum == tasks as u64
                && rep.chain.tasks_created == tasks as u64
                && rep.chain.tasks_executed == tasks as u64
        },
    );
}
