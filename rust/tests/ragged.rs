//! Ragged-partition regression suite (ISSUE 9 satellites): awkward
//! sizes where the block size does not divide the agent count, or the
//! lattice side is odd. Every engine must agree with the sequential
//! reference bitwise at these sizes, at both odd and even step counts —
//! an off-by-one in the ragged tail block or a double-buffer swap bug
//! shows up as a divergence here long before it corrupts a full-size
//! run.

use adapar::models::ising::{IsingModel, IsingParams};
use adapar::models::sir::{SirModel, SirParams};
use adapar::protocol::{ParallelEngine, ProtocolConfig, SequentialEngine, StepwiseEngine};
use adapar::sched::{ShardedConfig, ShardedEngine};
use adapar::sim::graph::{contiguous_partition, grid_partition};
use adapar::vtime::{CostModel, VirtualEngine};
use adapar::Layout;

// --------------------------------------------------- partition geometry

#[test]
fn ragged_contiguous_partitions_tell_one_story() {
    // 257 = 16×16 + 1: a one-agent tail block. 255 = 15×16 + 15: a
    // near-full tail block. Both must agree between the parameter-level
    // block count and the partition itself.
    for (agents, s) in [(257usize, 16usize), (255, 16), (100, 100), (1, 16)] {
        let params = SirParams::scaled(s, agents, 1);
        let p = contiguous_partition(agents, s);
        assert_eq!(p.blocks(), params.blocks(), "agents={agents} s={s}");
        assert_eq!(p.n(), agents, "agents={agents} s={s}");
        let mut covered = 0usize;
        for b in 0..p.blocks() {
            let members = p.members(b);
            assert!(!members.is_empty(), "agents={agents} s={s}: empty block {b}");
            assert!(
                members.len() <= s,
                "agents={agents} s={s}: block {b} exceeds subset size"
            );
            for &v in members {
                assert_eq!(p.block_of(v as usize), b as u32, "agents={agents} s={s}");
            }
            covered += members.len();
        }
        assert_eq!(covered, agents, "agents={agents} s={s}: cover");
        // The tail block holds exactly the remainder.
        let tail = p.members(p.blocks() - 1).len();
        let expect = if agents % s == 0 { s.min(agents) } else { agents % s };
        assert_eq!(tail, expect, "agents={agents} s={s}: tail size");
    }
}

#[test]
fn odd_lattice_grid_partitions_cover_and_stay_disjoint() {
    // 255² with power-of-two part counts: every tiling is ragged in both
    // dimensions.
    let (rows, cols) = (255usize, 255usize);
    for parts in [2usize, 4, 8, 16, 31] {
        let p = grid_partition(rows, cols, parts);
        assert_eq!(p.blocks(), parts, "parts={parts}");
        assert_eq!(p.n(), rows * cols, "parts={parts}");
        let mut covered = 0usize;
        for b in 0..p.blocks() {
            let members = p.members(b);
            assert!(!members.is_empty(), "parts={parts}: empty block {b}");
            for &v in members {
                assert_eq!(p.block_of(v as usize), b as u32, "parts={parts}");
            }
            covered += members.len();
        }
        assert_eq!(covered, rows * cols, "parts={parts}: cover");
    }
}

// -------------------------------------- SIR at ragged sizes, 5 engines

/// Raw final state of a SIR run under `run`, at the given layout.
fn sir_state(
    agents: usize,
    subset: usize,
    steps: u64,
    layout: Layout,
    run: &dyn Fn(&SirModel),
) -> Vec<u8> {
    let m = SirModel::with_layout(SirParams::scaled(subset, agents, steps), 5, layout);
    run(&m);
    m.snapshot()
}

#[test]
fn sir_ragged_tail_is_bitwise_identical_on_every_engine() {
    let seed = 11;
    // Odd and even step counts: after an odd number of compute+swap
    // steps a double-buffer discipline bug (publishing the wrong buffer,
    // or skipping the tail block's swap) leaves the buffers crossed.
    for (agents, subset) in [(257usize, 16usize), (255, 16)] {
        for steps in [9u64, 10] {
            for layout in [Layout::Legacy, Layout::Packed] {
                let reference = sir_state(agents, subset, steps, layout, &|m| {
                    SequentialEngine::new(seed).run(m);
                });
                let label = format!("agents={agents} s={subset} steps={steps} layout={layout}");
                let par = sir_state(agents, subset, steps, layout, &|m| {
                    ParallelEngine::new(ProtocolConfig {
                        workers: 2,
                        seed,
                        ..Default::default()
                    })
                    .run(m);
                });
                assert_eq!(par, reference, "parallel {label}");
                let step = sir_state(agents, subset, steps, layout, &|m| {
                    StepwiseEngine::new(2, seed).run(m);
                });
                assert_eq!(step, reference, "stepwise {label}");
                let shard = sir_state(agents, subset, steps, layout, &|m| {
                    ShardedEngine::new(ShardedConfig {
                        workers: 2,
                        seed,
                        ..Default::default()
                    })
                    .run(m);
                });
                assert_eq!(shard, reference, "sharded {label}");
                let virt = sir_state(agents, subset, steps, layout, &|m| {
                    VirtualEngine {
                        workers: 2,
                        tasks_per_cycle: 6,
                        seed,
                        cost: CostModel::default(),
                        trace: adapar::TraceMode::Off,
                        window: 0,
                    }
                    .run(m);
                });
                assert_eq!(virt, reference, "virtual {label}");
            }
        }
    }
}

#[test]
fn sir_census_is_consistent_at_ragged_sizes() {
    for layout in [Layout::Legacy, Layout::Packed, Layout::PackedLinear] {
        let m = SirModel::with_layout(SirParams::scaled(16, 257, 9), 5, layout);
        SequentialEngine::new(11).run(&m);
        let (s, i, r) = m.census();
        assert_eq!(s + i + r, 257, "{layout}: census must cover every agent");
        let snap = m.snapshot();
        assert_eq!(snap.len(), 257, "{layout}");
        assert_eq!(snap.iter().filter(|&&h| h == 0).count(), s, "{layout}");
        assert_eq!(snap.iter().filter(|&&h| h == 1).count(), i, "{layout}");
        assert_eq!(snap.iter().filter(|&&h| h == 2).count(), r, "{layout}");
    }
}

// --------------------------------------------------- Ising at odd side

#[test]
fn ising_odd_side_sharded_matches_sequential() {
    let params = IsingParams {
        side: 33, // odd side: every grid tiling is ragged
        temperature: 2.269,
        steps: 5_000,
    };
    let seed = 29;
    for layout in [Layout::Legacy, Layout::Packed] {
        let reference = {
            let m = IsingModel::with_layout(params, 4, layout);
            SequentialEngine::new(seed).run(&m);
            m.snapshot()
        };
        for workers in [2usize, 4] {
            let m = IsingModel::with_layout(params, 4, layout);
            ShardedEngine::new(ShardedConfig {
                workers,
                seed,
                ..Default::default()
            })
            .run(&m);
            assert_eq!(
                m.snapshot(),
                reference,
                "ising side=33 sharded n={workers} layout={layout}"
            );
        }
    }
}
