//! Record correctness, property-tested: every model's record must be
//! **conservative** with respect to an explicit read/write-footprint
//! oracle — `footprints conflict ⇒ depends() == true` — and should be
//! exact (no false positives) for the pairwise models, where the record
//! *is* the footprint check.

use std::collections::BTreeSet;

use adapar::model::Record as _;
use adapar::models::axelrod::{AxelrodModel, AxelrodParams, Interaction};
use adapar::models::ising::{FlipAttempt, IsingModel, IsingParams};
use adapar::models::sir::{SirModel, SirParams, SirPhase, SirTask};
use adapar::models::voter::{VoterModel, VoterParams, VoterStep};
use adapar::model::Model;
use adapar::sim::graph::ring_lattice;
use adapar::util::prop::{check, ranged_usize, vec_of, Config, Gen, PairOf};

/// Oracle: conflict between footprints (r1, w1) and (r2, w2).
fn conflicts(
    r1: &BTreeSet<u32>,
    w1: &BTreeSet<u32>,
    r2: &BTreeSet<u32>,
    w2: &BTreeSet<u32>,
) -> bool {
    w1.iter().any(|x| r2.contains(x) || w2.contains(x))
        || w2.iter().any(|x| r1.contains(x) || w1.contains(x))
}

fn set(xs: &[u32]) -> BTreeSet<u32> {
    xs.iter().copied().collect()
}

#[test]
fn axelrod_record_equals_footprint_oracle() {
    let model = AxelrodModel::new(
        AxelrodParams {
            agents: 12,
            features: 4,
            ..Default::default()
        },
        0,
    );
    // Generate sequences of (source, target) pairs over 12 agents.
    let gen = vec_of(
        PairOf(ranged_usize(0, 11), ranged_usize(0, 11)),
        1,
        12,
    );
    check("axelrod record == oracle", Config { cases: 128, ..Default::default() }, gen, |pairs| {
        let tasks: Vec<Interaction> = pairs
            .iter()
            .filter(|(s, t)| s != t)
            .map(|&(s, t)| Interaction {
                source: s as u32,
                target: t as u32,
            })
            .collect();
        if tasks.is_empty() {
            return true;
        }
        let (probe, absorbed) = tasks.split_last().unwrap();
        let mut rec = model.record();
        let mut reads = BTreeSet::new();
        let mut writes = BTreeSet::new();
        for t in absorbed {
            rec.absorb(t);
            reads.insert(t.source);
            reads.insert(t.target);
            writes.insert(t.target);
        }
        let probe_r = set(&[probe.source, probe.target]);
        let probe_w = set(&[probe.target]);
        let oracle = conflicts(&probe_r, &probe_w, &reads, &writes);
        rec.depends(probe) == oracle
    });
}

#[test]
fn voter_record_equals_footprint_oracle() {
    let model = VoterModel::new(ring_lattice(16, 4), VoterParams::default(), 0);
    let gen = vec_of(PairOf(ranged_usize(0, 15), ranged_usize(0, 15)), 1, 10);
    check("voter record == oracle", Config { cases: 128, ..Default::default() }, gen, |pairs| {
        let tasks: Vec<VoterStep> = pairs
            .iter()
            .filter(|(a, b)| a != b)
            .map(|&(a, b)| VoterStep {
                speaker: a as u32,
                listener: b as u32,
            })
            .collect();
        if tasks.is_empty() {
            return true;
        }
        let (probe, absorbed) = tasks.split_last().unwrap();
        let mut rec = model.record();
        let mut reads = BTreeSet::new();
        let mut writes = BTreeSet::new();
        for t in absorbed {
            rec.absorb(t);
            reads.insert(t.speaker);
            reads.insert(t.listener);
            writes.insert(t.listener);
        }
        let probe_r = set(&[probe.speaker, probe.listener]);
        let probe_w = set(&[probe.listener]);
        let oracle = conflicts(&probe_r, &probe_w, &reads, &writes);
        rec.depends(probe) == oracle
    });
}

#[test]
fn ising_record_is_conservative_over_neighbourhoods() {
    let model = IsingModel::new(
        IsingParams {
            side: 6,
            ..Default::default()
        },
        0,
    );
    let n = 36;
    let nbrs = |i: u32| -> BTreeSet<u32> {
        let g = adapar::sim::graph::lattice2d(6);
        let mut s: BTreeSet<u32> = g.neighbors(i as usize).iter().copied().collect();
        s.insert(i);
        s
    };
    let gen = vec_of(ranged_usize(0, n - 1), 1, 8);
    check("ising record conservative", Config { cases: 96, ..Default::default() }, gen, |sites| {
        let (probe, absorbed) = sites.split_last().unwrap();
        let probe = FlipAttempt { site: *probe as u32 };
        let mut rec = model.record();
        let mut reads = BTreeSet::new();
        let mut writes = BTreeSet::new();
        for &s in absorbed {
            let t = FlipAttempt { site: s as u32 };
            rec.absorb(&t);
            reads.extend(nbrs(s as u32));
            writes.insert(s as u32);
        }
        let probe_r = nbrs(probe.site);
        let probe_w = set(&[probe.site]);
        let oracle = conflicts(&probe_r, &probe_w, &reads, &writes);
        // Conservative: oracle conflict must imply depends.
        !oracle || rec.depends(&probe)
    });
}

#[test]
fn sir_record_is_conservative_over_block_footprints() {
    let params = SirParams::scaled(25, 200, 10);
    let model = SirModel::new(params, 0);
    let blocks = model.blocks();
    // Footprints in *agent* space: compute(b) reads cur[b ∪ nbr-agents],
    // writes new[b] (disjoint address space — model `new` as ids + N).
    let g = model.graph().clone();
    let members: Vec<Vec<u32>> = (0..blocks)
        .map(|b| model.partition().members(b).to_vec())
        .collect();
    let n = params.agents as u32;
    let compute_reads = |b: usize| -> BTreeSet<u32> {
        let mut s = BTreeSet::new();
        for &a in &members[b] {
            s.insert(a);
            for &nb in g.neighbors(a as usize) {
                s.insert(nb);
            }
        }
        s
    };
    let compute_writes = |b: usize| -> BTreeSet<u32> {
        members[b].iter().map(|&a| a + n).collect() // `new` rows
    };
    let swap_reads = |b: usize| -> BTreeSet<u32> {
        members[b].iter().map(|&a| a + n).collect()
    };
    let swap_writes = |b: usize| -> BTreeSet<u32> {
        members[b].iter().copied().collect() // `cur` rows
    };

    // The SIR record's soundness relies on a *chain-order invariant*: the
    // source emits compute(0..P) then swap(0..P) per step, and a task can
    // only be complete once all tasks it depends on are complete. The
    // oracle therefore generates only protocol-reachable pending sets: walk
    // the real source order, mark tasks complete only when every earlier
    // conflicting task is complete, probe a random incomplete task, absorb
    // the incomplete tasks before it.
    let footprint = |t: &SirTask| -> (BTreeSet<u32>, BTreeSet<u32>) {
        let b = t.block as usize;
        match t.phase {
            SirPhase::Compute => (compute_reads(b), compute_writes(b)),
            SirPhase::Swap => (swap_reads(b), swap_writes(b)),
        }
    };
    // Enumerate three steps of source order.
    let mut order: Vec<SirTask> = Vec::new();
    for _step in 0..3 {
        for b in 0..blocks {
            order.push(SirTask { phase: SirPhase::Compute, block: b as u32 });
        }
        for b in 0..blocks {
            order.push(SirTask { phase: SirPhase::Swap, block: b as u32 });
        }
    }
    let m = order.len();
    let gen = PairOf(
        vec_of(ranged_usize(0, 1), m, m), // completion coin flips
        ranged_usize(0, m - 1),           // probe position
    );
    check(
        "sir record conservative on reachable states",
        Config { cases: 96, ..Default::default() },
        gen,
        |(coins, probe_pos)| {
            let mut complete = vec![false; m];
            for i in 0..m {
                if coins[i] == 1 {
                    let (ri, wi) = footprint(&order[i]);
                    let deps_done = (0..i).all(|j| {
                        let (rj, wj) = footprint(&order[j]);
                        !conflicts(&ri, &wi, &rj, &wj) || complete[j]
                    });
                    if deps_done {
                        complete[i] = true;
                    }
                }
            }
            let p = *probe_pos;
            if complete[p] {
                return true; // probe must be an incomplete task
            }
            let probe = order[p];
            let mut rec = model.record();
            let mut reads = BTreeSet::new();
            let mut writes = BTreeSet::new();
            for j in 0..p {
                if !complete[j] {
                    rec.absorb(&order[j]);
                    let (rj, wj) = footprint(&order[j]);
                    reads.extend(rj);
                    writes.extend(wj);
                }
            }
            let (pr, pw) = footprint(&probe);
            let oracle = conflicts(&pr, &pw, &reads, &writes);
            !oracle || rec.depends(&probe)
        },
    );
}
