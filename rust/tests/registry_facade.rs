//! The redesigned execution API, end to end: every registered model runs
//! on every legal engine through the `Simulation` facade; unknown
//! model/engine names produce listings of the valid ones; and — the
//! acceptance test for the registry — a model the library does **not**
//! bundle is registered at runtime and then driven through the
//! coordinator's sweep machinery with zero coordinator edits.

use adapar::api::registry as registry_api;
use adapar::coordinator::config::SweepConfig;
use adapar::coordinator::{run_once, run_sweep};
use adapar::model::{Model, Record, TaskSource};
use adapar::sim::rng::TaskRng;
use adapar::sim::state::SharedSim;
use adapar::util::u32set::U32Set;
use adapar::vtime::CostModel;
use adapar::{Engine, EngineKind, ObsValue, Simulation};

#[test]
fn every_registered_model_runs_on_every_legal_engine_via_the_facade() {
    for model in registry_api::model_names() {
        let info = registry_api::info(&model).unwrap();
        let mut engines = vec![
            EngineKind::Sequential,
            EngineKind::Parallel,
            EngineKind::Virtual,
        ];
        if info.has_sync_form {
            engines.push(EngineKind::Stepwise);
        }
        if info.has_sharded_form {
            engines.push(EngineKind::Sharded);
        }
        for engine in engines {
            let out = Simulation::builder()
                .model(model.clone())
                .engine(engine)
                .workers(2)
                .agents(120)
                .steps(40)
                .size(10)
                .seed(1)
                .run()
                .unwrap_or_else(|e| panic!("{model}/{engine}: {e:#}"));
            assert!(out.report.time_s >= 0.0, "{model}/{engine}");
            assert!(!out.observable.is_empty(), "{model}/{engine}");
            assert!(
                !out.observable.final_frame().unwrap().values.is_empty(),
                "{model}/{engine}: bundled models must export typed metrics"
            );
            assert_eq!(out.report.engine, engine.to_string(), "{model}/{engine}");
        }
        // Engines the model does not support fail with a clear message.
        if !info.has_sync_form {
            let err = Simulation::builder()
                .model(model.clone())
                .engine(EngineKind::Stepwise)
                .agents(120)
                .steps(40)
                .size(10)
                .run()
                .unwrap_err();
            assert!(err.to_string().contains("no synchronous form"), "{model}");
        }
        if !info.has_sharded_form {
            let err = Simulation::builder()
                .model(model.clone())
                .engine(EngineKind::Sharded)
                .agents(120)
                .steps(40)
                .size(10)
                .run()
                .unwrap_err();
            assert!(
                err.to_string().contains("no footprint topology"),
                "{model}: {err}"
            );
        }
    }
}

#[test]
fn unknown_names_list_the_valid_choices() {
    let err = Simulation::builder().model("warpdrive").run().unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("unknown model `warpdrive`"), "{msg}");
    for name in registry_api::model_names() {
        assert!(msg.contains(&name), "{msg} should list {name}");
    }

    let err = "teleport".parse::<EngineKind>().unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("unknown engine `teleport`"), "{msg}");
    for engine in ["parallel", "sequential", "virtual", "stepwise", "sharded"] {
        assert!(msg.contains(engine), "{msg} should list {engine}");
    }
}

// ---------------------------------------------------------------------------
// A model the library does not bundle: `blinker` — each task toggles one
// cell of a shared bit array. Registered at runtime, then driven through
// `run_once`/`run_sweep` exactly like a bundled model.
// ---------------------------------------------------------------------------

struct BlinkerModel {
    cells: SharedSim<Vec<u8>>,
    tasks: u64,
}

#[derive(Clone, Copy, Debug)]
struct Toggle {
    cell: u32,
}

struct BlinkerRecord {
    seen: U32Set,
}

impl Record for BlinkerRecord {
    type Recipe = Toggle;
    fn depends(&self, r: &Toggle) -> bool {
        self.seen.contains(r.cell)
    }
    fn absorb(&mut self, r: &Toggle) {
        self.seen.insert(r.cell);
    }
    fn reset(&mut self) {
        self.seen.clear();
    }
}

struct BlinkerSource {
    next: u64,
    tasks: u64,
    cells: u32,
}

impl TaskSource for BlinkerSource {
    type Recipe = Toggle;
    fn next_task(&mut self) -> Option<Toggle> {
        if self.next >= self.tasks {
            return None;
        }
        // A deterministic but scattered cell sequence.
        let cell = ((self.next * 7 + 3) % self.cells as u64) as u32;
        self.next += 1;
        Some(Toggle { cell })
    }
    fn size_hint(&self) -> Option<u64> {
        Some(self.tasks)
    }
}

impl Model for BlinkerModel {
    type Recipe = Toggle;
    type Record = BlinkerRecord;
    type Source = BlinkerSource;

    fn source(&self, _seed: u64) -> BlinkerSource {
        BlinkerSource {
            next: 0,
            tasks: self.tasks,
            cells: unsafe { self.cells.get() }.len() as u32,
        }
    }

    fn record(&self) -> BlinkerRecord {
        BlinkerRecord {
            seen: U32Set::new(),
        }
    }

    fn execute(&self, r: &Toggle, _rng: &mut TaskRng) {
        unsafe {
            let cells = self.cells.get_mut();
            cells[r.cell as usize] ^= 1;
        }
    }
}

fn register_blinker_once() {
    use std::sync::Once;
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        let info = adapar::ModelInfo::new("blinker", "toggling bit array (test-only plug-in)")
            .sizes(&[4, 16])
            .agents(64, 64)
            .steps(500, 500);
        registry_api::register(info, |ctx| {
            let model = BlinkerModel {
                cells: SharedSim::new(vec![0u8; ctx.agents.max(1)]),
                tasks: ctx.steps,
            };
            Ok(adapar::Runnable::new("blinker", model)
                .observed(|m| {
                    let ones = unsafe { m.cells.get() }.iter().filter(|&&c| c == 1).count();
                    vec![("ones".to_string(), ObsValue::Int(ones as i64))]
                })
                .boxed())
        })
        .unwrap();
    });
}

#[test]
fn runtime_registered_model_runs_through_the_coordinator_unchanged() {
    register_blinker_once();
    let cost = CostModel::default();

    // `run_once` — the coordinator's single-run dispatch — needs no edits.
    let cfg = SweepConfig {
        model: "blinker".to_string(),
        engine: EngineKind::Parallel,
        sizes: vec![4],
        workers: vec![2],
        seeds: vec![1],
        ..Default::default()
    };
    cfg.validate().unwrap();
    let out = run_once(&cfg, 4, 2, 1, &cost).unwrap();
    assert_eq!(out.totals.executed, 500);
    assert!(
        out.observations.to_string().starts_with("ones="),
        "{}",
        out.observations
    );
    assert!(matches!(
        out.observations.value("ones"),
        Some(ObsValue::Int(_))
    ));

    // Determinism across engines holds for the plug-in, too.
    let observable = |engine| {
        let cfg = SweepConfig {
            engine,
            ..cfg.clone()
        };
        run_once(&cfg, 4, 3, 9, &cost).unwrap().observations
    };
    let seq = observable(EngineKind::Sequential);
    assert_eq!(observable(EngineKind::Parallel), seq);
    assert_eq!(observable(EngineKind::Virtual), seq);

    // The full sweep grid works off the registry defaults (empty `sizes`
    // resolves to the model's registered grid).
    let sweep = SweepConfig {
        model: "blinker".to_string(),
        engine: EngineKind::Virtual,
        sizes: Vec::new(),
        workers: vec![1, 2],
        seeds: vec![1, 2],
        ..Default::default()
    };
    let res = run_sweep(&sweep).unwrap();
    assert_eq!(res.points.len(), 4, "2 default sizes × 2 worker counts");
    assert!(res.points.iter().all(|p| p.mean_s > 0.0));
}

#[test]
fn runtime_registered_model_parses_from_sweep_toml() {
    register_blinker_once();
    let cfg = SweepConfig::from_toml(
        "model = \"blinker\"\nengine = \"virtual\"\nworkers = [2]\nseeds = [5]\n",
    )
    .unwrap();
    assert_eq!(cfg.model, "blinker");
    assert_eq!(cfg.effective_sizes(), vec![4, 16], "registry default grid");
    assert_eq!(cfg.effective_agents(), 64);
}

#[test]
fn boxed_engines_dispatch_uniformly() {
    // The object-safe Engine surface: one loop, four backends, one report
    // type.
    let tele = adapar::TelemetryMode::env_default();
    let trc = adapar::TraceMode::Off;
    let engines: Vec<Box<dyn Engine>> = vec![
        adapar::engine_for(EngineKind::Sequential, 1, 6, 16, 0, 3, CostModel::default(), tele, trc),
        adapar::engine_for(EngineKind::Parallel, 2, 6, 16, 0, 3, CostModel::default(), tele, trc),
        adapar::engine_for(EngineKind::Virtual, 2, 6, 16, 0, 3, CostModel::default(), tele, trc),
    ];
    let model = registry_api::build(
        "voter",
        &adapar::BuildCtx {
            size: 1,
            agents: 100,
            steps: 500,
            seed: 3,
            layout: Default::default(),
            params: adapar::Params::new(),
        },
    )
    .unwrap();
    for engine in engines {
        let report = engine.run(model.as_ref()).unwrap();
        assert_eq!(report.engine, engine.name());
        assert_eq!(report.totals.executed, 500, "{}", engine.name());
    }
}
