//! Statistical sanity for the RNG layer (ISSUE 9 satellite): the
//! bounded-draw path `Rng::below` uses Lemire's multiply-shift with
//! rejection, so it must be *unbiased* — a plain `x % bound` would tilt
//! low values by up to `2^64 mod bound` draws. These tests pin that with
//! a chi-square goodness-of-fit check at fixed seeds (the generator is
//! deterministic, so the statistics are exact reproducible numbers, not
//! flaky samples), plus coverage and determinism checks, and the
//! downstream claim that voter initialization spreads opinions evenly.

use adapar::models::voter::{VoterModel, VoterParams};
use adapar::sim::graph::ring_lattice;
use adapar::sim::rng::Rng;
use adapar::Layout;

/// Chi-square statistic of `draws` samples of `below(k)` under `rng`.
fn chi_square(rng: &mut Rng, k: u64, draws: u64) -> f64 {
    let mut counts = vec![0u64; k as usize];
    for _ in 0..draws {
        let v = rng.below(k);
        assert!(v < k, "below({k}) returned {v}");
        counts[v as usize] += 1;
    }
    let expected = draws as f64 / k as f64;
    counts
        .iter()
        .map(|&c| {
            let d = c as f64 - expected;
            d * d / expected
        })
        .sum()
}

#[test]
fn below_passes_chi_square_at_fixed_seeds() {
    // Thresholds: the statistic is chi-square distributed with k-1
    // degrees of freedom (mean k-1, variance 2(k-1)); mean + 6 sigma is
    // far beyond the 99.9th percentile, and the draws are deterministic
    // at a fixed seed, so a failure means bias, not bad luck. The
    // bounds deliberately include k = 3, 7, 10, 100 — none a power of
    // two, so a modulo-biased implementation would tilt them.
    const DRAWS: u64 = 200_000;
    for seed in [1u64, 0xDEAD_BEEF] {
        for k in [3u64, 7, 10, 100] {
            let df = (k - 1) as f64;
            let threshold = df + 6.0 * (2.0 * df).sqrt() + 4.0;
            let mut rng = Rng::stream(seed, 0x57A7);
            let stat = chi_square(&mut rng, k, DRAWS);
            assert!(
                stat < threshold,
                "below({k}) seed={seed}: chi-square {stat:.2} >= {threshold:.2} \
                 over {DRAWS} draws — the bounded-draw path looks biased"
            );
        }
    }
}

#[test]
fn below_covers_the_full_range() {
    // Every residue in [0, k) must be reachable, including k-1 (the
    // value a truncation bug would drop).
    let mut rng = Rng::stream(7, 0xC0FE);
    let k = 16u64;
    let mut seen = vec![false; k as usize];
    for _ in 0..10_000 {
        seen[rng.below(k) as usize] = true;
    }
    assert!(
        seen.iter().all(|&s| s),
        "below({k}) missed a residue in 10k draws: {seen:?}"
    );
    // Degenerate bound: below(1) is always 0.
    for _ in 0..100 {
        assert_eq!(rng.below(1), 0);
    }
}

#[test]
fn below_is_deterministic_at_a_fixed_seed() {
    let mut a = Rng::stream(42, 3);
    let mut b = Rng::stream(42, 3);
    let xs: Vec<u64> = (0..64).map(|_| a.below(1_000)).collect();
    let ys: Vec<u64> = (0..64).map(|_| b.below(1_000)).collect();
    assert_eq!(xs, ys, "identical streams must agree draw for draw");
    let mut c = Rng::stream(43, 3);
    let zs: Vec<u64> = (0..64).map(|_| c.below(1_000)).collect();
    assert_ne!(xs, zs, "different seeds must decorrelate");
}

#[test]
fn voter_initialization_spreads_opinions_evenly() {
    // The voter factory draws initial opinions with `below(opinions)`;
    // with 2 000 agents and 3 opinions each tally should be near 667.
    // The seed is fixed, so the bound is a deterministic regression
    // check on the init stream, not a flaky sample.
    for layout in [Layout::Legacy, Layout::Packed] {
        let m = VoterModel::with_layout(
            ring_lattice(2_000, 6),
            VoterParams {
                opinions: 3,
                steps: 1,
            },
            6,
            layout,
        );
        let tally = m.tally();
        assert_eq!(tally.iter().sum::<usize>(), 2_000, "{layout}");
        for (op, &count) in tally.iter().enumerate() {
            assert!(
                (500..=850).contains(&count),
                "{layout}: opinion {op} holds {count} of 2000 agents — \
                 the init stream looks skewed ({tally:?})"
            );
        }
    }
}
