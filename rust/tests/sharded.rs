//! Acceptance suite for the sharded adaptive scheduler (ISSUE 3 +
//! ISSUE 4's lattice models): the `sharded` engine must produce
//! **byte-identical final states and epoch observation traces** to the
//! sequential engine for SIR, Axelrod, voter, Ising and
//! bounded-relocation Schelling at fixed seeds, across worker counts.
//! The registry-driven matrix in `rust/tests/conformance.rs` extends
//! the same property to every registered model × engine combination.
//!
//! CI runs this suite once per worker count (`ADAPAR_SHARDED_WORKERS`
//! pins the count for the matrix job); locally, all of 1/2/4 run.

use adapar::api::registry::{self, Params};
use adapar::model::testkit::{env_worker_counts as worker_counts, IncModel};
use adapar::models::axelrod::{AxelrodModel, AxelrodParams};
use adapar::models::sir::{SirModel, SirParams};
use adapar::models::voter::{VoterModel, VoterParams};
use adapar::protocol::SequentialEngine;
use adapar::sim::graph::ring_lattice;
use adapar::{EngineKind, ModelInfo, Runnable, ShardedConfig, ShardedEngine, Simulation};

/// Facade-level trace comparison: run `model` observed at `every` on the
/// sequential engine, then assert the sharded engine reproduces the
/// trace exactly at each worker count.
fn assert_traces_match(model: &str, agents: usize, steps: u64, size: usize, every: u64) {
    assert_traces_match_with(model, agents, steps, size, every, Params::new());
}

fn assert_traces_match_with(
    model: &str,
    agents: usize,
    steps: u64,
    size: usize,
    every: u64,
    params: Params,
) {
    let run = |engine: EngineKind, workers: usize| {
        Simulation::builder()
            .model(model)
            .engine(engine)
            .workers(workers)
            .agents(agents)
            .steps(steps)
            .size(size)
            .seed(17)
            .params(params.clone())
            .every(every)
            .run()
            .unwrap_or_else(|e| panic!("{model}/{engine}: {e}"))
            .observable
    };
    let reference = run(EngineKind::Sequential, 1);
    assert!(
        reference.len() > 2,
        "{model}: cadence {every} must yield a multi-frame trace"
    );
    for workers in worker_counts() {
        let got = run(EngineKind::Sharded, workers);
        assert_eq!(got, reference, "{model} sharded n={workers} trace diverged");
    }
}

#[test]
fn sir_trace_is_byte_identical_to_sequential() {
    assert_traces_match("sir", 400, 40, 25, 500);
}

#[test]
fn axelrod_trace_is_byte_identical_to_sequential() {
    // Complete-graph pairs: nearly everything crosses shards, stressing
    // the spillover chain and its fences.
    assert_traces_match("axelrod", 80, 4_000, 12, 1_000);
}

#[test]
fn voter_trace_is_byte_identical_to_sequential() {
    assert_traces_match("voter", 300, 8_000, 1, 2_000);
}

#[test]
fn ising_trace_is_byte_identical_to_sequential() {
    // 2D lattice: the grid hint routes the engine to the strip/block
    // tiling (ISSUE 4's lattice-native sharding).
    assert_traces_match("ising", 256, 6_000, 1, 1_500);
}

#[test]
fn bounded_schelling_trace_is_byte_identical_to_sequential() {
    let mut params = Params::new();
    params.set("move_radius", 2i64);
    assert_traces_match_with("schelling", 300, 8_000, 1, 2_000, params);
}

#[test]
fn sir_final_states_match_across_granularities() {
    for s in [10usize, 30, 150] {
        let params = SirParams::scaled(s, 300, 40);
        let seed = 13;
        let reference = {
            let m = SirModel::new(params, 5);
            SequentialEngine::new(seed).run(&m);
            m.snapshot()
        };
        for workers in worker_counts() {
            let m = SirModel::new(params, 5);
            let report = ShardedEngine::new(ShardedConfig {
                workers,
                seed,
                ..Default::default()
            })
            .run(&m);
            assert_eq!(m.snapshot(), reference, "s={s} n={workers} diverged");
            assert_eq!(report.totals.executed, report.chain.tasks_executed);
        }
    }
}

#[test]
fn axelrod_final_states_match_with_heavy_spillover() {
    let params = AxelrodParams {
        agents: 60,
        features: 10,
        traits: 3,
        omega: 0.95,
        steps: 5_000,
    };
    let seed = 29;
    let reference = {
        let m = AxelrodModel::new(params, 3);
        SequentialEngine::new(seed).run(&m);
        m.snapshot()
    };
    for workers in worker_counts() {
        let m = AxelrodModel::new(params, 3);
        let report = ShardedEngine::new(ShardedConfig {
            workers,
            seed,
            ..Default::default()
        })
        .run(&m);
        assert_eq!(m.snapshot(), reference, "n={workers} diverged");
        let sched = report.sched.as_ref().unwrap();
        assert_eq!(sched.local_tasks + sched.boundary_tasks, 5_000);
        if workers > 1 {
            assert!(
                sched.boundary_tasks > 0,
                "complete-graph pairs must cross shards: {sched:?}"
            );
        }
    }
}

#[test]
fn voter_final_states_match_under_aggressive_rebalancing() {
    let seed = 7;
    let make = || {
        VoterModel::new(
            ring_lattice(240, 6),
            VoterParams {
                opinions: 3,
                steps: 12_000,
            },
            11,
        )
    };
    let reference = {
        let m = make();
        SequentialEngine::new(seed).run(&m);
        m.snapshot()
    };
    for workers in worker_counts() {
        let m = make();
        let report = ShardedEngine::new(ShardedConfig {
            workers,
            seed,
            rebalance_every: 512, // force many epoch boundaries + migrations
            ..Default::default()
        })
        .run(&m);
        assert_eq!(m.snapshot(), reference, "n={workers} diverged");
        let sched = report.sched.as_ref().unwrap();
        assert!(sched.rebalances > 0, "short epochs must hit the rebalancer");
    }
}

#[test]
fn sharded_report_carries_sched_telemetry_through_the_facade() {
    let out = Simulation::builder()
        .model("sir")
        .engine(EngineKind::Sharded)
        .workers(2)
        .agents(200)
        .steps(20)
        .size(20)
        .seed(7)
        .run()
        .unwrap();
    assert_eq!(out.report.engine, "sharded");
    let sched = out.report.sched.as_ref().expect("sharded reports telemetry");
    assert_eq!(sched.local_tasks + sched.boundary_tasks, 20 * 2 * 10);
    assert!(out.report.to_json().render().contains("\"sched\""));
    // Per-worker ids are wired through to the report.
    for (w, stats) in out.report.per_worker.iter().enumerate() {
        assert_eq!(stats.worker, w);
    }
}

#[test]
fn sharded_refuses_models_without_a_topology() {
    // Every bundled model is shard-capable now, so register a test
    // double that deliberately omits `with_sharding` — the capability
    // gate must still refuse it with a clear message.
    registry::register(
        ModelInfo::new("no-topology", "test double without a footprint topology"),
        |ctx| Ok(Runnable::new("no-topology", IncModel::new(ctx.steps.max(1), 8)).boxed()),
    )
    .expect("fresh name registers");
    let err = Simulation::builder()
        .model("no-topology")
        .engine(EngineKind::Sharded)
        .agents(100)
        .steps(50)
        .run()
        .unwrap_err();
    assert!(
        err.to_string().contains("no footprint topology"),
        "{err}"
    );
}
