//! SoA state-layer acceptance (ISSUE 9): the bit-packed layout is pure
//! storage. Relabeling is a pure permutation of agent ids; every layout
//! produces byte-identical state trajectories on every engine × worker
//! count; and the packed stores actually shrink the per-task byte
//! footprint on the migrated models.

use adapar::model::testkit::{env_layouts, env_worker_counts};
use adapar::model::Model;
use adapar::models::ising::{IsingModel, IsingParams};
use adapar::models::sir::{SirModel, SirParams};
use adapar::models::voter::{VoterModel, VoterParams};
use adapar::protocol::{ParallelEngine, ProtocolConfig, SequentialEngine, StepwiseEngine};
use adapar::sched::{ShardedConfig, ShardedEngine};
use adapar::sim::graph::{
    bfs_partition, contiguous_partition, grid_partition, ring_lattice, Partition,
};
use adapar::vtime::{CostModel, VirtualEngine};
use adapar::{Layout, Relabeling};

// ------------------------------------------------------------ relabeling

fn assert_pure_permutation(rel: &Relabeling, label: &str) {
    assert!(rel.is_permutation(), "{label}: not a permutation");
    let n = rel.len();
    // relabel ∘ inverse == identity, in both directions.
    for a in 0..n {
        let s = rel.slot_of(a) as usize;
        assert_eq!(rel.agent_of(s) as usize, a, "{label}: agent {a}");
    }
    let inv = rel.inverse();
    assert!(inv.is_permutation(), "{label}: inverse not a permutation");
    for a in 0..n {
        assert_eq!(
            inv.slot_of(rel.agent_of(a) as usize) as usize,
            a,
            "{label}: inverse ∘ relabel at {a}"
        );
    }
    // Every slot hit exactly once.
    let mut seen = vec![false; n];
    for a in 0..n {
        let s = rel.slot_of(a) as usize;
        assert!(!seen[s], "{label}: slot {s} assigned twice");
        seen[s] = true;
    }
}

#[test]
fn relabelings_from_partitions_are_pure_permutations() {
    let cases: Vec<(&str, Partition)> = vec![
        ("contiguous 257/16", contiguous_partition(257, 16)),
        ("contiguous 255/16", contiguous_partition(255, 16)),
        ("bfs ring 257/7", bfs_partition(&ring_lattice(257, 6), 7)),
        ("grid 13x19/5", grid_partition(13, 19, 5)),
        ("grid 255x255/16", grid_partition(255, 255, 16)),
    ];
    for (label, p) in &cases {
        let rel = Relabeling::from_partition(p);
        assert_eq!(rel.len(), p.n(), "{label}");
        assert_pure_permutation(&rel, label);
        // Each block's slots are contiguous — the locality property the
        // packed layout exists for.
        let mut next = 0u32;
        for b in 0..p.blocks() {
            for &a in p.members(b) {
                assert_eq!(rel.slot_of(a as usize), next, "{label}: block {b}");
                next += 1;
            }
        }
    }
    // A contiguous partition relabels to the identity.
    assert!(Relabeling::from_partition(&contiguous_partition(257, 16)).is_identity());
    assert_pure_permutation(&Relabeling::identity(100), "identity 100");
}

// ------------------------------------- layout equivalence, five engines

/// SIR at a deliberately ragged size (257 agents, subset 16 → 17 blocks,
/// one-member tail): the raw final state buffer must be byte-identical
/// across every layout × engine × worker count, at several trajectory
/// depths.
#[test]
fn every_engine_and_layout_agree_on_the_sir_trajectory() {
    let seed = 23;
    for steps in [10u64, 50, 200] {
        let params = SirParams::scaled(16, 257, steps);
        let reference = {
            let m = SirModel::with_layout(params, 5, Layout::Legacy);
            SequentialEngine::new(seed).run(&m);
            m.snapshot()
        };
        for layout in env_layouts() {
            let run_and_snapshot = |run: &dyn Fn(&SirModel)| {
                let m = SirModel::with_layout(params, 5, layout);
                run(&m);
                m.snapshot()
            };
            let seq = run_and_snapshot(&|m| {
                SequentialEngine::new(seed).run(m);
            });
            assert_eq!(seq, reference, "sequential layout={layout} steps={steps}");
            for &workers in &env_worker_counts() {
                let par = run_and_snapshot(&|m| {
                    ParallelEngine::new(ProtocolConfig {
                        workers,
                        seed,
                        ..Default::default()
                    })
                    .run(m);
                });
                assert_eq!(par, reference, "parallel n={workers} layout={layout} steps={steps}");
                let step = run_and_snapshot(&|m| {
                    StepwiseEngine::new(workers, seed).run(m);
                });
                assert_eq!(step, reference, "stepwise n={workers} layout={layout} steps={steps}");
                let shard = run_and_snapshot(&|m| {
                    ShardedEngine::new(ShardedConfig {
                        workers,
                        seed,
                        ..Default::default()
                    })
                    .run(m);
                });
                assert_eq!(shard, reference, "sharded n={workers} layout={layout} steps={steps}");
                let virt = run_and_snapshot(&|m| {
                    VirtualEngine {
                        workers,
                        tasks_per_cycle: 6,
                        seed,
                        cost: CostModel::default(),
                        trace: adapar::TraceMode::Off,
                        window: 0,
                    }
                    .run(m);
                });
                assert_eq!(virt, reference, "virtual n={workers} layout={layout} steps={steps}");
            }
        }
    }
}

#[test]
fn voter_and_ising_layouts_agree_on_raw_state() {
    let seed = 31;
    // Voter on a ring lattice.
    let vparams = VoterParams {
        opinions: 3,
        steps: 3_000,
    };
    let vref = {
        let m = VoterModel::with_layout(ring_lattice(200, 6), vparams, 6, Layout::Legacy);
        SequentialEngine::new(seed).run(&m);
        m.snapshot()
    };
    for layout in env_layouts() {
        let m = VoterModel::with_layout(ring_lattice(200, 6), vparams, 6, layout);
        ParallelEngine::new(ProtocolConfig {
            workers: 2,
            seed,
            ..Default::default()
        })
        .run(&m);
        assert_eq!(m.snapshot(), vref, "voter layout={layout}");
        assert_eq!(
            m.tally().iter().sum::<usize>(),
            200,
            "voter layout={layout}: tally covers all agents"
        );
    }
    // Ising on a small torus.
    let iparams = IsingParams {
        side: 20,
        temperature: 2.269,
        steps: 4_000,
    };
    let iref = {
        let m = IsingModel::with_layout(iparams, 4, Layout::Legacy);
        SequentialEngine::new(seed).run(&m);
        m.snapshot()
    };
    for layout in env_layouts() {
        let m = IsingModel::with_layout(iparams, 4, layout);
        ParallelEngine::new(ProtocolConfig {
            workers: 2,
            seed,
            ..Default::default()
        })
        .run(&m);
        assert_eq!(m.snapshot(), iref, "ising layout={layout}");
    }
}

// ------------------------------------------------------- byte footprint

#[test]
fn packed_layouts_shrink_state_bytes_per_task() {
    let sir = |layout| {
        SirModel::with_layout(SirParams::scaled(16, 257, 10), 5, layout).state_bytes_per_task()
    };
    let voter = |layout| {
        VoterModel::with_layout(
            ring_lattice(200, 6),
            VoterParams {
                opinions: 3,
                steps: 100,
            },
            6,
            layout,
        )
        .state_bytes_per_task()
    };
    let ising = |layout| {
        IsingModel::with_layout(
            IsingParams {
                side: 20,
                temperature: 2.269,
                steps: 100,
            },
            4,
            layout,
        )
        .state_bytes_per_task()
    };
    for (name, f) in [
        ("sir", &sir as &dyn Fn(Layout) -> f64),
        ("voter", &voter),
        ("ising", &ising),
    ] {
        let legacy = f(Layout::Legacy);
        assert!(legacy > 0.0, "{name}: legacy estimate must be positive");
        for layout in [Layout::Packed, Layout::PackedLinear] {
            assert!(
                f(layout) < legacy,
                "{name} {layout}: packed must move fewer bytes than legacy \
                 ({} vs {legacy})",
                f(layout)
            );
        }
    }
}
