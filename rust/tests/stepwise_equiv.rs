//! The step-parallel baseline must be semantically equivalent to the chain
//! engines on synchronous models (same per-(step, phase, block) RNG
//! streams), across worker counts and granularities.

use adapar::models::sir::{SirModel, SirParams};
use adapar::protocol::{ParallelEngine, ProtocolConfig, SequentialEngine, StepwiseEngine};

#[test]
fn stepwise_equals_sequential_and_protocol() {
    for s in [10usize, 40, 100] {
        let params = SirParams::scaled(s, 400, 50);
        let seed = 17;
        let reference = {
            let m = SirModel::new(params, 4);
            SequentialEngine::new(seed).run(&m);
            m.snapshot()
        };
        for workers in [1, 2, 4] {
            let m = SirModel::new(params, 4);
            let report = StepwiseEngine::new(workers, seed).run(&m);
            assert_eq!(m.snapshot(), reference, "stepwise s={s} n={workers}");
            assert_eq!(report.engine, "stepwise");
            let blocks = m.blocks() as u64;
            assert_eq!(report.totals.executed, 50 * 2 * blocks);
        }
        let m = SirModel::new(params, 4);
        ParallelEngine::new(ProtocolConfig {
            workers: 3,
            seed,
            ..Default::default()
        })
        .run(&m);
        assert_eq!(m.snapshot(), reference, "protocol s={s}");
    }
}

#[test]
fn stepwise_respects_phase_barriers() {
    // With an uneven block count (not divisible by worker count), barrier
    // bugs manifest as divergent states; sweep worker counts.
    let params = SirParams::scaled(30, 330, 40); // 11 blocks
    let seed = 29;
    let reference = {
        let m = SirModel::new(params, 8);
        StepwiseEngine::new(1, seed).run(&m);
        m.snapshot()
    };
    for workers in [2, 3, 5] {
        let m = SirModel::new(params, 8);
        StepwiseEngine::new(workers, seed).run(&m);
        assert_eq!(m.snapshot(), reference, "n={workers}");
    }
}

#[test]
fn stepwise_census_is_plausible() {
    let params = SirParams::scaled(50, 500, 100);
    let m = SirModel::new(params, 2);
    let (s0, i0, r0) = m.census();
    assert_eq!(s0 + i0 + r0, 500);
    StepwiseEngine::new(2, 5).run(&m);
    let (s1, i1, r1) = m.census();
    assert_eq!(s1 + i1 + r1, 500, "agents conserved");
    assert!(r1 > 0 || i1 > 0, "epidemic ran");
    let _ = (s1, i0, r0);
}
