//! Streaming-window integration suite (ISSUE 10): the materialization
//! window is semantically inert back-pressure. For every window — the
//! degenerate `W = 1`, an awkward prime, a deep window, and windows
//! ragged against the workload's task count — results must be
//! byte-identical to the materialized run, while the arena's high-water
//! mark stays pinned at `W + 2` sentinel slots instead of tracking the
//! workload. The cross-engine trace axis lives in
//! `rust/tests/conformance.rs` (`streaming_windows_are_invisible_in_
//! every_trace`); this suite drills the chain- and facade-level
//! mechanics.

use adapar::model::testkit::{env_stream_windows, IncModel};
use adapar::protocol::{ParallelEngine, ProtocolConfig, RunReport};
use adapar::{EngineKind, Simulation};

fn inc_run(tasks: u64, workers: usize, c: u32, window: u64) -> (RunReport, Vec<u64>) {
    let m = IncModel::new(tasks, 32);
    let rep = ParallelEngine::new(ProtocolConfig {
        workers,
        tasks_per_cycle: c,
        batch: 16,
        seed: 41,
        window,
        ..Default::default()
    })
    .run(&m);
    (rep, m.cells_snapshot())
}

// ------------------------------------------------------- chain level

#[test]
fn every_window_reproduces_the_materialized_run() {
    // Windows from the shared axis ({0, 1, 7, 64} unless pinned),
    // against the materialized reference, across worker counts.
    let (ref_rep, reference) = inc_run(2_000, 1, 6, 0);
    assert_eq!(ref_rep.totals.executed, 2_000);
    for window in env_stream_windows() {
        for workers in [1usize, 2, 4] {
            let (rep, cells) = inc_run(2_000, workers, 6, window);
            assert_eq!(cells, reference, "n={workers} W={window}");
            assert_eq!(rep.totals.executed, 2_000, "n={workers} W={window}");
            if window > 0 {
                assert!(
                    rep.chain.arena_high_water as u64 <= window + 2,
                    "n={workers} W={window}: high-water {} escaped the window",
                    rep.chain.arena_high_water
                );
            }
        }
    }
}

#[test]
fn ragged_tails_drain_completely() {
    // Task counts deliberately ragged against the window: W ∤ tasks,
    // W = tasks (exact), W = tasks ± 1, and W ≫ tasks. Exhaustion, not
    // a stall, must close the source in every case — a latched stall
    // here shows up as a hang or a short count.
    for tasks in [1u64, 13, 100] {
        for window in [1u64, 7, tasks.saturating_sub(1).max(1), tasks, tasks + 1, 4_096] {
            let (rep, cells) = inc_run(tasks, 2, 6, window);
            let (_, reference) = inc_run(tasks, 2, 6, 0);
            assert_eq!(cells, reference, "tasks={tasks} W={window}");
            assert_eq!(rep.totals.executed, tasks, "tasks={tasks} W={window}");
        }
    }
}

#[test]
fn window_pins_high_water_while_materialized_tracks_the_workload() {
    // Single worker, C = 64: materialized, each cycle creates up to 64
    // and drains one, so the live set — and with it both the high-water
    // mark and the arena's chunk footprint — tracks the workload.
    // Streamed through W = 7 the same run holds ≤ 9 slots and never
    // grows past its (power-of-two-rounded) pre-size.
    const TASKS: u64 = 20_000;
    let (mat, mat_cells) = inc_run(TASKS, 1, 64, 0);
    let (st, st_cells) = inc_run(TASKS, 1, 64, 7);
    assert_eq!(st_cells, mat_cells);
    assert!(
        mat.chain.arena_high_water as u64 > TASKS / 2,
        "materialized single-worker high-water should track the workload, got {}",
        mat.chain.arena_high_water
    );
    assert!(
        st.chain.arena_high_water <= 9,
        "streamed high-water {} escaped W + 2",
        st.chain.arena_high_water
    );
    assert!(
        st.chain.arena_capacity <= 256,
        "streamed arena grew past its windowed pre-size: {}",
        st.chain.arena_capacity
    );
    assert!(
        (mat.chain.arena_capacity as u64) >= TASKS,
        "materialized arena must have materialized the workload: {}",
        mat.chain.arena_capacity
    );
}

// ------------------------------------------------------ facade level

#[test]
fn facade_streaming_is_invisible_in_sir_observations() {
    // Model-level check through the public facade: a multi-epoch SIR
    // run (observation cadence forces epoch boundaries, which exercise
    // reopen + shrink-on-quiesce under streaming) yields the identical
    // observation trace at every window, on both chain engines.
    let run = |engine: EngineKind, window: u64| {
        Simulation::builder()
            .model("sir")
            .engine(engine)
            .workers(2)
            .tasks_per_cycle(8)
            .batch(8)
            .agents(300)
            .steps(400)
            .size(20)
            .seed(13)
            .every(128)
            .window(window)
            .run()
            .unwrap_or_else(|e| panic!("{engine} W={window}: {e}"))
    };
    let reference = run(EngineKind::Parallel, 0);
    assert!(reference.observable.len() > 1, "need a multi-frame trace");
    for window in [1u64, 7, 64] {
        for engine in [EngineKind::Parallel, EngineKind::Sharded] {
            let out = run(engine, window);
            assert_eq!(
                out.observable, reference.observable,
                "{engine} W={window}: trace diverged"
            );
            if engine == EngineKind::Parallel {
                assert!(
                    out.report.chain.arena_high_water as u64 <= window + 2,
                    "{engine} W={window}: high-water {} escaped",
                    out.report.chain.arena_high_water
                );
            }
        }
    }
}

#[test]
fn default_window_applies_through_the_builder() {
    // `.window(DEFAULT_WINDOW)` (what `--streaming` resolves to) on a
    // virtual-time run: same T, same trace, bounded node pool.
    use adapar::model::DEFAULT_WINDOW;
    let run = |window: u64| {
        Simulation::builder()
            .model("voter")
            .engine(EngineKind::Virtual)
            .workers(3)
            .agents(200)
            .steps(3_000)
            .seed(19)
            .every(1_000)
            .window(window)
            .run()
            .unwrap()
    };
    let mat = run(0);
    let st = run(DEFAULT_WINDOW);
    // Observable (semantic) equality is the contract; the virtual T may
    // differ marginally because stalled creation draws still cost
    // `create_ns` on the drawing worker's clock.
    assert_eq!(st.observable, mat.observable, "virtual trace diverged");
    assert!(
        st.report.chain.arena_high_water as u64 <= DEFAULT_WINDOW + 2,
        "virtual high-water {} escaped the default window",
        st.report.chain.arena_high_water
    );
}
