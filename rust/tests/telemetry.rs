//! Telemetry subsystem integration tests (ISSUE 7): the inertness
//! contract (the sampling layer never changes results — on, off or
//! saturated), ring-overflow accounting, aggregator shutdown fencing,
//! the async sink's byte-identity guarantee, and the error-path drop
//! guard that leaves complete sink files behind red runs.

use adapar::api::observe::{AsyncSink, JsonLinesSink, ObsFrame, ObsValue, Observer, Sink, SinkSpec};
use adapar::model::testkit::env_telemetry_modes;
use adapar::telemetry::MetricsRegistry;
use adapar::util::json::Json;
use adapar::{EngineKind, ObservePlan, Simulation, TelemetryMode};

fn voter(engine: EngineKind, workers: usize, mode: TelemetryMode) -> adapar::SimOutcome {
    Simulation::builder()
        .model("voter")
        .engine(engine)
        .workers(workers)
        .batch(16)
        .tasks_per_cycle(16)
        .agents(240)
        .steps(4_000)
        .seed(7)
        .observe(ObservePlan::every(512))
        .telemetry(mode)
        .run()
        .unwrap_or_else(|e| panic!("voter/{engine} n={workers} {}: {e}", mode.label()))
}

/// The inertness contract, engine by engine: the observation trace (and
/// the lossless counters) are identical whether the ring/histogram layer
/// is on, off, or saturated down to 4-slot rings.
#[test]
fn telemetry_mode_is_semantically_inert() {
    let reference = voter(EngineKind::Sequential, 1, TelemetryMode::On);
    for mode in env_telemetry_modes() {
        for (engine, workers) in [
            (EngineKind::Sequential, 1),
            (EngineKind::Parallel, 3),
            (EngineKind::Sharded, 2),
        ] {
            let got = voter(engine, workers, mode);
            assert_eq!(
                got.observable,
                reference.observable,
                "{engine} n={workers} telemetry={}: trace diverged from sequential",
                mode.label()
            );
            assert_eq!(
                got.report.totals.executed, reference.report.totals.executed,
                "{engine} telemetry={}: executed-count drift",
                mode.label()
            );
            let snap = got
                .report
                .telemetry
                .as_ref()
                .unwrap_or_else(|| panic!("{engine}: report must carry a telemetry snapshot"));
            // Chainless engines publish post-hoc (counters only), so
            // their snapshot always reports mode "off".
            if engine != EngineKind::Sequential {
                assert_eq!(snap.mode(), mode);
            }
        }
    }
}

/// Saturated mode (4-slot rings) must drop samples on a real workload —
/// and that loss must stay confined to histograms: counters stay exact
/// and the trace stays byte-identical. The sharded engine samples
/// `exec_ns` on every task, so 4000 tasks give a dense stream no 4-slot
/// ring can absorb.
#[test]
fn saturated_rings_drop_samples_without_touching_results() {
    let on = voter(EngineKind::Sharded, 2, TelemetryMode::On);
    let sat = voter(EngineKind::Sharded, 2, TelemetryMode::Saturated);
    assert_eq!(sat.observable, on.observable, "saturation changed the trace");
    let snap = sat.report.telemetry.as_ref().unwrap();
    assert!(
        snap.dropped_total() > 0,
        "4-slot rings under 4000 per-task samples must overflow"
    );
    // The lossless layer is untouched by ring overflow.
    assert_eq!(snap.counter("worker.executed"), 4_000);
    assert_eq!(snap.counter("chain.tasks_executed"), 4_000);
    // Off mode reports no rings at all — dropped stays zero.
    let off = voter(EngineKind::Sharded, 2, TelemetryMode::Off);
    assert_eq!(off.report.telemetry.as_ref().unwrap().dropped_total(), 0);
}

/// Every push is either merged into a histogram or counted as dropped —
/// ring overflow is accounting, never silent loss or blocking.
#[test]
fn ring_overflow_conserves_every_sample() {
    let mut reg = MetricsRegistry::new();
    let h = reg.histogram("t.samples");
    let core = reg.start(1, TelemetryMode::Saturated); // 4-slot ring
    let total = 10_000u64;
    {
        let t = core.handle(0);
        for v in 0..total {
            t.sample(h, v);
        }
    }
    let snap = core.finish();
    let merged = snap.histogram("t.samples").expect("registered histogram");
    assert_eq!(
        merged.count() + snap.dropped_total(),
        total,
        "push conservation: merged + dropped must equal pushed"
    );
    assert!(
        snap.dropped_total() > 0,
        "a 4-slot ring cannot absorb 10k samples"
    );
}

/// The shutdown fence: everything pushed before `finish` lands in the
/// final histograms when the ring has room — the aggregator's last drain
/// runs after the stop flag, losing nothing.
#[test]
fn aggregator_shutdown_drains_every_pre_fence_sample() {
    let mut reg = MetricsRegistry::new();
    let h = reg.histogram("t.fenced");
    let c = reg.counter("t.count");
    let core = reg.start(2, TelemetryMode::On); // 4096-slot rings
    for w in 0..2 {
        let t = core.handle(w);
        for v in 0..1_000u64 {
            t.sample(h, v + 1);
            t.add(c, 1);
        }
    }
    core.record(c, 5); // engine-global row
    let snap = core.finish();
    assert_eq!(snap.dropped_total(), 0, "rings never filled");
    assert_eq!(snap.histogram("t.fenced").unwrap().count(), 2_000);
    assert_eq!(snap.histogram_worker("t.fenced", 0).unwrap().count(), 1_000);
    assert_eq!(snap.counter("t.count"), 2_005);
    assert_eq!(snap.counter_worker("t.count", 1), 1_000);
    // Counters survive Off mode too — they are the stats plumbing, not
    // an optional layer.
    let mut reg = MetricsRegistry::new();
    let c = reg.counter("t.count");
    let core = reg.start(1, TelemetryMode::Off);
    core.handle(0).add(c, 7);
    assert_eq!(core.finish().counter("t.count"), 7);
}

fn frames(n: u64) -> Vec<ObsFrame> {
    (0..n)
        .map(|i| ObsFrame {
            tasks: i * 100,
            values: vec![
                ("m".into(), ObsValue::Float(i as f64 / 3.0)),
                (
                    "census".into(),
                    ObsValue::counts([("S", 10 - i as i64), ("I", i as i64)]),
                ),
            ],
        })
        .collect()
}

/// The async adapter's contract: output bytes are identical to running
/// the wrapped sink synchronously (one consumer, FIFO channel).
#[test]
fn async_sink_output_is_byte_identical_to_sync() {
    let dir = std::env::temp_dir().join("adapar_telemetry_async_sink_test");
    std::fs::create_dir_all(&dir).unwrap();
    let sync_path = dir.join("sync.jsonl");
    let async_path = dir.join("async.jsonl");

    let mut sync_sink = JsonLinesSink::create(&sync_path).unwrap();
    let mut async_sink =
        AsyncSink::with_depth(Box::new(JsonLinesSink::create(&async_path).unwrap()), 2);
    for frame in frames(10) {
        sync_sink.record(&frame).unwrap();
        async_sink.record(&frame).unwrap();
    }
    sync_sink.finish().unwrap();
    async_sink.finish().unwrap();
    async_sink.finish().unwrap(); // the flush fence is idempotent

    let sync_bytes = std::fs::read(&sync_path).unwrap();
    let async_bytes = std::fs::read(&async_path).unwrap();
    assert!(!sync_bytes.is_empty());
    assert_eq!(sync_bytes, async_bytes, "async output must match sync byte-for-byte");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The error-path guard (ISSUE satellite): dropping an unfinished
/// `Observer` — what happens when an engine error unwinds past
/// `finish` — still flushes and closes every attached sink, so a red
/// run leaves a complete, parseable JSON-lines file.
#[test]
fn dropped_observer_leaves_complete_sink_files() {
    let dir = std::env::temp_dir().join("adapar_telemetry_drop_guard_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("red_run.jsonl");
    {
        let mut obs = Observer::new(1);
        obs.add_sink(SinkSpec::JsonLines(path.clone()).build(None).unwrap());
        for frame in frames(5) {
            obs.record(frame.tasks, frame.values);
        }
        // No `finish`: the run "failed" here. Drop must flush anyway.
    }
    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 5, "all recorded frames must reach the file");
    for line in lines {
        let obj = Json::parse(line).expect("every line must be complete JSON");
        assert!(matches!(obj, Json::Obj(_)));
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite 1: the `--json` report carries one coherent `telemetry`
/// object, and the legacy stats blocks are exact views over it.
#[test]
fn report_stats_are_views_over_the_registry_snapshot() {
    let out = voter(EngineKind::Sharded, 2, TelemetryMode::On);
    let report = &out.report;
    let snap = report.telemetry.as_ref().unwrap();

    assert_eq!(snap.counter("worker.executed"), report.totals.executed);
    assert_eq!(snap.counter("worker.created"), report.totals.created);
    for (w, per) in report.per_worker.iter().enumerate() {
        assert_eq!(snap.counter_worker("worker.executed", w), per.executed);
    }
    assert_eq!(snap.counter("chain.tasks_executed"), report.chain.tasks_executed);
    assert_eq!(snap.counter("chain.tail_locks"), report.chain.tail_locks);

    let sched = report.sched.as_ref().expect("sharded run has sched stats");
    assert_eq!(snap.counter("sched.local_tasks"), sched.local_tasks);
    assert_eq!(snap.counter("sched.boundary_tasks"), sched.boundary_tasks);
    assert_eq!(
        snap.counter("sched.backpressure_stalls"),
        sched.backpressure_stalls
    );
    for (k, &locks) in sched.per_shard_tail_locks.iter().enumerate() {
        assert_eq!(
            snap.counter(&format!("sched.shard{k}.tail_locks")),
            locks,
            "shard {k} tail-lock view"
        );
    }

    let json = report.to_json().render();
    assert!(json.contains("\"telemetry\":{"), "{json}");
    assert!(json.contains("\"counters\":{"), "{json}");
    assert!(json.contains("\"histograms\":{"), "{json}");
    assert!(json.contains("\"dropped_total\":"), "{json}");
}

/// Chainless engines publish post-hoc, so their reports carry the same
/// coherent snapshot shape as the chain engines.
#[test]
fn chainless_engines_carry_snapshots_too() {
    for engine in [EngineKind::Sequential, EngineKind::Virtual] {
        let out = voter(engine, 1, TelemetryMode::On);
        let snap = out.report.telemetry.as_ref().unwrap();
        assert_eq!(
            snap.counter("worker.executed"),
            out.report.totals.executed,
            "{engine}"
        );
        assert_eq!(snap.dropped_total(), 0, "{engine}: no rings, no drops");
    }
}

/// TelemetryMode parsing round-trips the CLI/env spellings.
#[test]
fn telemetry_mode_parses_cli_spellings() {
    assert_eq!("on".parse::<TelemetryMode>().unwrap(), TelemetryMode::On);
    assert_eq!("off".parse::<TelemetryMode>().unwrap(), TelemetryMode::Off);
    assert_eq!(
        "saturate".parse::<TelemetryMode>().unwrap(),
        TelemetryMode::Saturated
    );
    assert_eq!(
        "saturated".parse::<TelemetryMode>().unwrap(),
        TelemetryMode::Saturated
    );
    assert!("loud".parse::<TelemetryMode>().is_err());
    assert_eq!(TelemetryMode::default(), TelemetryMode::On);
}
