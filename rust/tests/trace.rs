//! Causal-trace invariants (ISSUE 8): real models, real engines, full
//! tracing — asserting the structural contract the Perfetto exporter
//! and the critical-path analyzer rely on:
//!
//! * per-lane spans form a laminar family (nest or are disjoint, never
//!   partially overlap);
//! * causal edges point strictly forward on the `(start_ns, index)`
//!   order (hence acyclic) and fence edges respect fence discipline
//!   (work span → later work span);
//! * `T1` equals the sum of the work-span durations, `T∞ ≤ T1`, and the
//!   sequential engine's total program order forces `T∞ == T1`;
//! * the exported Perfetto JSON validates structurally and parses back
//!   to the identical trace.

use adapar::trace::{analyze, perfetto, EdgeKind, EventKind, Trace};
use adapar::{EngineKind, Simulation, TraceMode};

/// Traced run of a registered model through the facade.
fn traced(model: &str, engine: EngineKind, workers: usize, mode: TraceMode) -> Trace {
    let out = Simulation::builder()
        .model(model)
        .engine(engine)
        .workers(workers)
        .agents(150)
        .steps(2_000)
        .size(8)
        .seed(41)
        .trace(mode)
        .run()
        .unwrap_or_else(|e| panic!("{model}/{engine} n={workers}: {e:#}"));
    out.report
        .trace
        .unwrap_or_else(|| panic!("{model}/{engine}: tracing on but no trace in the report"))
}

/// The engines a model supports, out of the ones this suite exercises.
fn engines_for(model: &str) -> Vec<EngineKind> {
    let info = adapar::api::registry::info(model).unwrap();
    [EngineKind::Sequential, EngineKind::Parallel, EngineKind::Sharded]
        .into_iter()
        .filter(|&e| info.supports(e))
        .collect()
}

/// Laminar check for one lane: sorted by `(start, -end)`, every span
/// either starts at/after the enclosing span's end (disjoint) or ends
/// at/before it (nested). A partial overlap is a recording bug.
fn assert_lane_spans_laminar(trace: &Trace, lane: u32, ctx: &str) {
    let mut spans: Vec<(u64, u64)> = trace
        .events
        .iter()
        .filter(|e| e.lane == lane && e.kind.is_span())
        .map(|e| (e.start_ns, e.end_ns()))
        .collect();
    spans.sort_by(|a, b| a.0.cmp(&b.0).then(b.1.cmp(&a.1)));
    let mut open: Vec<(u64, u64)> = Vec::new();
    for &(start, end) in &spans {
        while matches!(open.last(), Some(&(_, oe)) if oe <= start) {
            open.pop();
        }
        if let Some(&(os, oe)) = open.last() {
            assert!(
                end <= oe,
                "{ctx} lane {lane}: span [{start}, {end}) partially overlaps [{os}, {oe})"
            );
        }
        open.push((start, end));
    }
}

#[test]
fn spans_nest_and_never_overlap_per_worker() {
    for model in ["voter", "sir"] {
        for engine in engines_for(model) {
            let trace = traced(model, engine, 2, TraceMode::Full);
            assert!(!trace.events.is_empty(), "{model}/{engine}: empty trace");
            for lane in 0..=trace.workers as u32 {
                assert_lane_spans_laminar(&trace, lane, &format!("{model}/{engine}"));
            }
        }
    }
}

#[test]
fn causal_edges_are_acyclic_and_respect_fence_discipline() {
    for model in ["voter", "sir"] {
        for engine in engines_for(model) {
            let trace = traced(model, engine, 2, TraceMode::Full);
            for edge in &trace.edges {
                let from = &trace.events[edge.from];
                let to = &trace.events[edge.to];
                // Strictly forward on (start, index): the acyclicity
                // invariant — any cycle would need a backward edge.
                assert!(
                    (from.start_ns, edge.from) < (to.start_ns, edge.to),
                    "{model}/{engine}: backward edge {edge:?}"
                );
                // Every causal edge connects task work to task work.
                assert!(
                    from.kind.is_work() && to.kind.is_work(),
                    "{model}/{engine}: edge on non-work spans {edge:?}"
                );
                if edge.kind == EdgeKind::Fence {
                    // Fence discipline: the source is the fenced
                    // boundary task's own span, released strictly
                    // before the sink ran.
                    assert_ne!(from.task, adapar::trace::NONE_ID, "{model}/{engine}");
                }
                if edge.kind == EdgeKind::Footprint {
                    // Footprint edges follow canonical task order on a
                    // shared block.
                    assert_eq!(from.block, to.block, "{model}/{engine}: {edge:?}");
                    assert!(from.task < to.task, "{model}/{engine}: {edge:?}");
                }
            }
        }
    }
}

#[test]
fn t1_is_the_sum_of_work_spans_and_bounds_tinf() {
    for model in ["voter", "sir"] {
        for engine in engines_for(model) {
            for workers in [1usize, 3] {
                let trace = traced(model, engine, workers, TraceMode::Full);
                let a = analyze::analyze(&trace);
                let sum: u64 = trace
                    .work_spans()
                    .iter()
                    .map(|&i| trace.events[i].dur_ns)
                    .sum();
                assert_eq!(a.t1_ns, sum, "{model}/{engine} n={workers}: T1 != Σ exec");
                assert!(
                    a.tinf_ns <= a.t1_ns,
                    "{model}/{engine} n={workers}: T∞ {} > T1 {}",
                    a.tinf_ns,
                    a.t1_ns
                );
                // The attribution components always sum to the gap.
                let parts: f64 = a.attribution.components().iter().map(|(_, v)| v).sum();
                assert!(
                    (parts - a.attribution.gap_ns).abs() < 1e-6 * a.attribution.gap_ns.max(1.0),
                    "{model}/{engine} n={workers}: attribution {} != gap {}",
                    parts,
                    a.attribution.gap_ns
                );
                // Per-epoch bounds obey the same law.
                for e in &a.epochs {
                    assert!(e.tinf_ns <= a.t1_ns, "{model}/{engine}: epoch {e:?}");
                }
            }
        }
    }
}

#[test]
fn sequential_traces_have_t1_equal_tinf() {
    for model in ["voter", "sir"] {
        let trace = traced(model, EngineKind::Sequential, 1, TraceMode::Full);
        // Program order chains every pair of consecutive work spans.
        let order = trace
            .edges
            .iter()
            .filter(|e| e.kind == EdgeKind::Order)
            .count();
        let work = trace.work_spans().len();
        assert!(work > 0, "{model}: no work spans");
        assert_eq!(order, work - 1, "{model}: broken program-order chain");
        let a = analyze::analyze(&trace);
        assert_eq!(
            a.t1_ns, a.tinf_ns,
            "{model}: a total order leaves no parallelism, T∞ must equal T1"
        );
        assert!((a.speedup_bound - 1.0).abs() < 1e-9, "{model}");
    }
}

#[test]
fn work_spans_match_executed_tasks_when_lossless() {
    for model in ["voter", "sir"] {
        for engine in engines_for(model) {
            let out = Simulation::builder()
                .model(model)
                .engine(engine)
                .workers(2)
                .agents(150)
                .steps(2_000)
                .size(8)
                .seed(41)
                .trace(TraceMode::Spans)
                .run()
                .unwrap_or_else(|e| panic!("{model}/{engine}: {e:#}"));
            let trace = out.report.trace.as_ref().unwrap();
            if trace.dropped == 0 {
                assert_eq!(
                    trace.work_spans().len() as u64,
                    out.report.totals.executed,
                    "{model}/{engine}: one work span per executed task"
                );
            }
            for i in trace.work_spans() {
                let e = &trace.events[i];
                assert!(matches!(e.kind, EventKind::Exec | EventKind::Spill));
                assert_ne!(e.task, adapar::trace::NONE_ID, "{model}/{engine}");
            }
        }
    }
}

#[test]
fn perfetto_export_validates_and_round_trips() {
    for model in ["voter"] {
        for engine in engines_for(model) {
            let trace = traced(model, engine, 2, TraceMode::Full);
            let text = perfetto::export(&trace);
            let n = perfetto::validate_structure(&text)
                .unwrap_or_else(|e| panic!("{model}/{engine}: invalid Perfetto JSON: {e}"));
            assert!(n > 0, "{model}/{engine}: empty traceEvents");
            let back = perfetto::parse(&text)
                .unwrap_or_else(|e| panic!("{model}/{engine}: round-trip parse: {e}"));
            assert_eq!(back.engine, trace.engine, "{model}/{engine}");
            assert_eq!(back.workers, trace.workers, "{model}/{engine}");
            assert_eq!(back.mode, trace.mode, "{model}/{engine}");
            assert_eq!(back.basis, trace.basis, "{model}/{engine}");
            assert_eq!(back.events, trace.events, "{model}/{engine}");
            assert_eq!(back.edges, trace.edges, "{model}/{engine}");
            assert_eq!(back.epoch_marks, trace.epoch_marks, "{model}/{engine}");
            assert_eq!(back.dropped, trace.dropped, "{model}/{engine}");
            // The analyzer sees the identical critical path either way.
            let (a, b) = (analyze::analyze(&trace), analyze::analyze(&back));
            assert_eq!(a.t1_ns, b.t1_ns, "{model}/{engine}");
            assert_eq!(a.tinf_ns, b.tinf_ns, "{model}/{engine}");
        }
    }
}
