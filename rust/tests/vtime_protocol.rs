//! Virtual testbed invariants: state fidelity across all real models,
//! counter consistency, determinism, and the qualitative speedup shapes
//! the paper's figures rely on.

use adapar::model::testkit::IncModel;
use adapar::models::axelrod::{AxelrodModel, AxelrodParams};
use adapar::models::sir::{SirModel, SirParams};
use adapar::protocol::SequentialEngine;
use adapar::vtime::{CostModel, VirtualEngine};

fn engine(workers: usize, seed: u64) -> VirtualEngine {
    VirtualEngine {
        workers,
        tasks_per_cycle: 6,
        seed,
        cost: CostModel::default(),
        trace: adapar::TraceMode::Off,
        window: 0,
    }
}

#[test]
fn virtual_sir_matches_sequential_for_every_n() {
    let params = SirParams::scaled(25, 300, 40);
    let seed = 3;
    let reference = {
        let m = SirModel::new(params, 1);
        SequentialEngine::new(seed).run(&m);
        m.snapshot()
    };
    for n in 1..=5 {
        let m = SirModel::new(params, 1);
        let rep = engine(n, seed).run(&m);
        assert_eq!(m.snapshot(), reference, "n={n}");
        assert_eq!(rep.totals.executed, rep.totals.created);
        assert_eq!(rep.totals.executed, 40 * 2 * m.blocks() as u64);
    }
}

#[test]
fn virtual_axelrod_speedup_grows_with_task_size() {
    // The Fig. 2 mechanism: the T(1)/T(n) ratio must increase with F
    // because protocol overhead amortizes over the O(F) task body.
    let t = |features: usize, workers: usize| {
        let m = AxelrodModel::new(
            AxelrodParams {
                agents: 400,
                features,
                traits: 3,
                omega: 0.95,
                steps: 4_000,
            },
            2,
        );
        engine(workers, 5).run(&m).time_s
    };
    let ratio_small = t(8, 1) / t(8, 4);
    let ratio_large = t(200, 1) / t(200, 4);
    assert!(
        ratio_large > ratio_small,
        "speedup must grow with F: F=8 ratio {ratio_small:.2}, F=200 ratio {ratio_large:.2}"
    );
    assert!(ratio_large > 1.5, "large tasks must parallelize: {ratio_large:.2}");
}

#[test]
fn virtual_sir_fine_granularity_is_overhead_dominated() {
    // The Fig. 3 mechanism: total model work is constant in s, so tiny
    // subsets (many tasks) must cost more wall-clock than the plateau.
    let t = |s: usize| {
        let m = SirModel::new(SirParams::scaled(s, 400, 30), 1);
        engine(3, 7).run(&m).time_s
    };
    let t_fine = t(5);
    let t_plateau = t(100);
    assert!(
        t_fine > t_plateau * 1.5,
        "s=5 ({t_fine:.6}s) should be markedly slower than s=100 ({t_plateau:.6}s)"
    );
}

#[test]
fn virtual_time_monotone_in_task_cost() {
    let t = |work: u32| {
        let m = IncModel::with_work(1500, 32, work);
        engine(2, 1).run(&m).time_s
    };
    assert!(t(10) < t(1000));
    assert!(t(1000) < t(50_000));
}

#[test]
fn virtual_reports_are_reproducible() {
    let run = || {
        let m = SirModel::new(SirParams::scaled(20, 200, 30), 4);
        let r = engine(4, 9).run(&m);
        (r.time_s, r.totals.executed, r.totals.skipped_dependent, r.chain.max_chain_len)
    };
    assert_eq!(run(), run());
}

#[test]
fn worker_clocks_and_counters_are_consistent() {
    let m = IncModel::with_work(2000, 64, 200);
    let rep = engine(5, 11).run(&m);
    assert_eq!(rep.per_worker.len(), 5);
    let sum: u64 = rep.per_worker.iter().map(|w| w.executed).sum();
    assert_eq!(sum, rep.totals.executed);
    assert_eq!(rep.chain.tasks_created, 2000);
    // Every worker should have done *something* on this workload.
    assert!(rep.per_worker.iter().all(|w| w.cycles > 0));
}
