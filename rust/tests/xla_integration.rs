//! Three-layer integration: AOT artifacts (Python/JAX/Pallas) loaded and
//! executed from Rust via PJRT, validated **bitwise** against the native
//! models.
//!
//! Gated on `artifacts/manifest.txt` (built by `make artifacts`); each test
//! is skipped with a notice when artifacts are absent so plain
//! `cargo test` stays green in a fresh checkout.

use adapar::models::axelrod::{AxelrodModel, AxelrodParams, Interaction};
use adapar::models::sir::{SirModel, SirParams};
use adapar::protocol::SequentialEngine;
use adapar::runtime::xla_engine::{XlaAxelrodInteractor, XlaSirModel};
use adapar::runtime::{Manifest, XlaRuntime};
use adapar::sim::rng::TaskRng;

fn manifest() -> Option<Manifest> {
    let dir = Manifest::default_dir();
    if dir.join("manifest.txt").exists() {
        Some(Manifest::load(dir).expect("manifest parses"))
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

#[test]
fn axelrod_xla_matches_native_bitwise() {
    let Some(manifest) = manifest() else { return };
    let rt = XlaRuntime::cpu().expect("PJRT CPU client");
    let interactor = XlaAxelrodInteractor::from_manifest(&rt, &manifest).expect("load artifact");

    // Native model at the artifact's static shape.
    let params = AxelrodParams {
        agents: 60,
        features: interactor.features(),
        traits: 3,
        omega: interactor.omega(),
        steps: 400,
    };
    let seed = 99;
    let native = AxelrodModel::new(params, 7);
    let via_xla = AxelrodModel::new(params, 7);
    assert_eq!(native.snapshot(), via_xla.snapshot());

    // Drive both through the same task sequence: native execution vs
    // XLA-per-task execution fed from identical per-task streams.
    let mut source = adapar::model::Model::source(&native, seed);
    let mut seq = 0u64;
    while let Some(recipe) = adapar::model::TaskSource::next_task(&mut source) {
        let Interaction { source: s, target: t } = recipe;
        // Native path.
        let mut rng = TaskRng::for_task(seed, seq);
        adapar::model::Model::execute(&native, &recipe, &mut rng);
        // XLA path: same stream, same draws.
        let mut rng2 = TaskRng::for_task(seed, seq);
        let f = params.features;
        let (src_row, tgt_row): (Vec<i32>, Vec<i32>) = {
            let snap = via_xla.snapshot();
            (
                snap[s as usize * f..(s as usize + 1) * f].iter().map(|&x| x as i32).collect(),
                snap[t as usize * f..(t as usize + 1) * f].iter().map(|&x| x as i32).collect(),
            )
        };
        let u1 = rng2.unit_f64();
        let u2 = rng2.unit_f64();
        let new_tgt = interactor.interact(&src_row, &tgt_row, u1, u2).expect("interact");
        via_xla.write_agent_row(t as usize, &new_tgt);
        seq += 1;
    }
    assert_eq!(
        native.snapshot(),
        via_xla.snapshot(),
        "XLA and native Axelrod diverged"
    );
}

#[test]
fn sir_xla_model_matches_native_bitwise() {
    let Some(manifest) = manifest() else { return };
    let rt = XlaRuntime::cpu().expect("PJRT CPU client");

    // Shape must match the exported artifact: n=300, k=14, s=30.
    let params = SirParams::scaled(30, 300, 25);
    let seed = 5;

    let native = SirModel::new(params, 3);
    SequentialEngine::new(seed).run(&native);

    let xla_model = XlaSirModel::from_manifest(&rt, &manifest, SirModel::new(params, 3))
        .expect("load sir_block artifact");
    SequentialEngine::new(seed).run(&xla_model);

    assert_eq!(
        native.snapshot(),
        xla_model.snapshot(),
        "XLA and native SIR diverged"
    );
}

#[test]
fn manifest_artifacts_all_compile() {
    let Some(manifest) = manifest() else { return };
    let rt = XlaRuntime::cpu().expect("PJRT CPU client");
    assert!(rt.device_count() >= 1);
    assert_eq!(rt.platform(), "cpu");
    for entry in manifest.entries() {
        rt.load_hlo_text(&entry.path)
            .unwrap_or_else(|e| panic!("artifact {} failed to compile: {e:#}", entry.name));
    }
}
